//! Streaming nonzero updates: [`TensorDelta`] describes a batch of
//! appended / changed / removed elements, applied atomically to a
//! [`SparseTensor`].
//!
//! Delta semantics (the contract `coordinator::TuckerSession::ingest`
//! and the incremental plan invalidation build on):
//!
//! - **append** — a new nonzero at a coordinate within the existing mode
//!   lengths. It gets the next element id (ids are append-only and
//!   stable: no existing id ever moves).
//! - **change** — a new value for the *first* (lowest-id) existing
//!   element at the coordinate. Changes and removals address the tensor
//!   as it was *before* this delta's appends.
//! - **remove** — shorthand for a change to `0.0`. The element stays in
//!   the COO structure as an explicit zero, so every downstream id,
//!   slice index, policy assignment and plan stream stays valid; the
//!   element contributes exactly nothing to any TTM. (Compacting
//!   explicit zeros away is a rebuild-the-world operation by design —
//!   it would invalidate every id.)
//!
//! [`TensorDelta::apply`] is atomic: the whole batch is validated
//! against the tensor first, and the tensor is only mutated once no
//! operation can fail. A rejected delta leaves the tensor untouched.

use super::coo::{SparseTensor, MAX_NNZ};
use super::slices::SliceIndex;

/// A batch of streaming updates to a sparse tensor.
///
/// Value operations (changes and removals) keep their queue order: a
/// `remove` followed by a `change` of the same coordinate re-sets the
/// value, while the reverse order removes it — the last queued
/// operation on a coordinate wins, exactly as if applied one by one.
#[derive(Debug, Clone, Default)]
pub struct TensorDelta {
    appended: Vec<(Vec<u32>, f32)>,
    /// Changes and removals interleaved in queue order; removals carry
    /// value 0.0 and the flag.
    updates: Vec<(Vec<u32>, f32, bool)>,
}

impl TensorDelta {
    pub fn new() -> TensorDelta {
        TensorDelta::default()
    }

    /// Queue a new nonzero (builder style).
    pub fn append(mut self, coord: &[u32], val: f32) -> Self {
        self.appended.push((coord.to_vec(), val));
        self
    }

    /// Queue a value change for the first existing element at `coord`.
    pub fn change(mut self, coord: &[u32], val: f32) -> Self {
        self.updates.push((coord.to_vec(), val, false));
        self
    }

    /// Queue a removal (change to an explicit zero — see module docs).
    pub fn remove(mut self, coord: &[u32]) -> Self {
        self.updates.push((coord.to_vec(), 0.0, true));
        self
    }

    /// No queued operations?
    pub fn is_empty(&self) -> bool {
        self.appended.is_empty() && self.updates.is_empty()
    }

    /// Queued (appends, changes, removals) counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let removals = self.updates.iter().filter(|&&(_, _, rem)| rem).count();
        (self.appended.len(), self.updates.len() - removals, removals)
    }

    /// Validate the whole batch against `t` (using mode 0's slice index
    /// to locate changed/removed coordinates), then apply it. Returns
    /// the touched element ids; on any error the tensor is unchanged.
    pub fn apply(
        &self,
        t: &mut SparseTensor,
        idx: &[SliceIndex],
    ) -> Result<AppliedDelta, DeltaError> {
        let ndim = t.ndim();
        let check_coord = |coord: &[u32]| -> Result<(), DeltaError> {
            if coord.len() != ndim {
                return Err(DeltaError::ArityMismatch {
                    coord: coord.to_vec(),
                    ndim,
                });
            }
            for (n, &c) in coord.iter().enumerate() {
                if c >= t.dims[n] {
                    return Err(DeltaError::CoordOutOfRange {
                        coord: coord.to_vec(),
                        mode: n,
                        dim: t.dims[n],
                    });
                }
            }
            Ok(())
        };
        // --- validation pass: nothing is mutated until it succeeds ---
        if (t.nnz() as u64) + (self.appended.len() as u64) > MAX_NNZ {
            return Err(DeltaError::CapacityExceeded {
                nnz: t.nnz(),
                appends: self.appended.len(),
            });
        }
        for (coord, _) in &self.appended {
            check_coord(coord)?;
        }
        // locate changed/removed ids against the pre-append tensor: the
        // mode-0 slice holds candidate ids in ascending order, so the
        // first full-coordinate match is the lowest id
        let locate = |coord: &[u32]| -> Result<u32, DeltaError> {
            check_coord(coord)?;
            for &e in idx[0].slice(coord[0] as usize) {
                if (1..ndim).all(|n| t.coord(n, e as usize) == coord[n]) {
                    return Ok(e);
                }
            }
            Err(DeltaError::UnknownCoordinate { coord: coord.to_vec() })
        };
        // value ops resolve in queue order (last op on a coordinate
        // wins — a change queued after a removal re-sets the value)
        let mut changed: Vec<(u32, f32)> = Vec::with_capacity(self.updates.len());
        let mut removed_count = 0usize;
        for (coord, val, is_removal) in &self.updates {
            changed.push((locate(coord)?, *val));
            if *is_removal {
                removed_count += 1;
            }
        }
        // --- mutation pass (infallible) ---
        for &(e, val) in &changed {
            t.vals[e as usize] = val;
        }
        let first_new = t.nnz() as u32;
        for (coord, val) in &self.appended {
            t.push(coord, *val);
        }
        let mut changed_ids: Vec<u32> = changed.iter().map(|&(e, _)| e).collect();
        changed_ids.sort_unstable();
        changed_ids.dedup();
        Ok(AppliedDelta {
            appended: (first_new..t.nnz() as u32).collect(),
            changed: changed_ids,
            removed_count,
        })
    }
}

/// The element ids a successfully applied delta touched.
#[derive(Debug, Clone)]
pub struct AppliedDelta {
    /// Ids of the appended elements, ascending (they are the tail of the
    /// id space).
    pub appended: Vec<u32>,
    /// Ids whose value changed (removals included), ascending, deduped.
    pub changed: Vec<u32>,
    /// How many of the changes were removals (explicit zeros).
    pub removed_count: usize,
}

/// Why a [`TensorDelta`] could not be applied.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DeltaError {
    /// A coordinate names the wrong number of modes.
    ArityMismatch { coord: Vec<u32>, ndim: usize },
    /// A coordinate exceeds a mode length (deltas never grow the dims).
    CoordOutOfRange { coord: Vec<u32>, mode: usize, dim: u32 },
    /// A change/removal names a coordinate with no stored element.
    UnknownCoordinate { coord: Vec<u32> },
    /// The appends would push an element id past `u32` (see
    /// [`MAX_NNZ`]).
    CapacityExceeded { nnz: usize, appends: usize },
}

impl std::fmt::Display for DeltaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeltaError::ArityMismatch { coord, ndim } => {
                write!(f, "coordinate {coord:?} names {} modes, tensor has {ndim}", coord.len())
            }
            DeltaError::CoordOutOfRange { coord, mode, dim } => {
                write!(f, "coordinate {coord:?}: mode {mode} exceeds L_{mode}={dim}")
            }
            DeltaError::UnknownCoordinate { coord } => {
                write!(f, "no stored element at {coord:?} to change/remove")
            }
            DeltaError::CapacityExceeded { nnz, appends } => {
                write!(f, "{nnz} + {appends} elements would overflow u32 element ids")
            }
        }
    }
}

impl std::error::Error for DeltaError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::slices::build_all;

    fn small() -> (SparseTensor, Vec<SliceIndex>) {
        let mut t = SparseTensor::new(vec![4, 3, 2]);
        t.push(&[0, 0, 0], 1.0);
        t.push(&[1, 2, 1], 2.0);
        t.push(&[3, 1, 0], 3.0);
        let idx = build_all(&t);
        (t, idx)
    }

    #[test]
    fn apply_appends_changes_and_removes() {
        let (mut t, idx) = small();
        let delta = TensorDelta::new()
            .append(&[2, 2, 1], 4.0)
            .change(&[1, 2, 1], -2.0)
            .remove(&[0, 0, 0]);
        let applied = delta.apply(&mut t, &idx).unwrap();
        assert_eq!(applied.appended, vec![3]);
        assert_eq!(applied.changed, vec![0, 1]);
        assert_eq!(applied.removed_count, 1);
        assert_eq!(t.nnz(), 4, "removal keeps the explicit zero");
        assert_eq!(t.vals[0], 0.0);
        assert_eq!(t.vals[1], -2.0);
        assert_eq!(t.vals[3], 4.0);
        assert_eq!(t.coord(0, 3), 2);
    }

    #[test]
    fn change_targets_the_first_duplicate() {
        let mut t = SparseTensor::new(vec![2, 2]);
        t.push(&[1, 1], 5.0);
        t.push(&[1, 1], 7.0); // duplicate coordinate, higher id
        let idx = build_all(&t);
        let applied =
            TensorDelta::new().change(&[1, 1], 9.0).apply(&mut t, &idx).unwrap();
        assert_eq!(applied.changed, vec![0]);
        assert_eq!(t.vals, vec![9.0, 7.0]);
    }

    #[test]
    fn value_ops_resolve_in_queue_order() {
        // remove then re-set: the later change wins
        let (mut t, idx) = small();
        let applied = TensorDelta::new()
            .remove(&[1, 2, 1])
            .change(&[1, 2, 1], 6.0)
            .apply(&mut t, &idx)
            .unwrap();
        assert_eq!(t.vals[1], 6.0);
        assert_eq!(applied.removed_count, 1);
        assert_eq!(applied.changed, vec![1]);
        // change then remove: the removal wins
        let (mut t, idx) = small();
        TensorDelta::new()
            .change(&[1, 2, 1], 6.0)
            .remove(&[1, 2, 1])
            .apply(&mut t, &idx)
            .unwrap();
        assert_eq!(t.vals[1], 0.0);
    }

    #[test]
    fn rejected_delta_leaves_the_tensor_untouched() {
        let (mut t, idx) = small();
        let before = t.clone();
        // a valid change queued before an invalid one: atomicity means
        // neither applies
        let err = TensorDelta::new()
            .change(&[1, 2, 1], 10.0)
            .change(&[2, 0, 0], 1.0)
            .apply(&mut t, &idx)
            .unwrap_err();
        assert_eq!(err, DeltaError::UnknownCoordinate { coord: vec![2, 0, 0] });
        assert_eq!(t.vals, before.vals);
        let err = TensorDelta::new()
            .append(&[0, 0, 5], 1.0)
            .apply(&mut t, &idx)
            .unwrap_err();
        assert!(matches!(err, DeltaError::CoordOutOfRange { mode: 2, .. }));
        let err =
            TensorDelta::new().append(&[0, 0], 1.0).apply(&mut t, &idx).unwrap_err();
        assert!(matches!(err, DeltaError::ArityMismatch { .. }));
        assert_eq!(t.nnz(), before.nnz());
    }

    #[test]
    fn empty_delta_is_a_noop() {
        let (mut t, idx) = small();
        let delta = TensorDelta::new();
        assert!(delta.is_empty());
        let applied = delta.apply(&mut t, &idx).unwrap();
        assert!(applied.appended.is_empty() && applied.changed.is_empty());
    }
}
