//! Integration over the whole L3 stack: datasets → every scheme →
//! simulated cluster → HOOI → records, checking the cross-cutting
//! invariants the paper's evaluation relies on.

use tucker_lite::coordinator::{run_scheme, Workload};
use tucker_lite::dist::NetModel;
use tucker_lite::runtime::Engine;
use tucker_lite::sched::{self, Scheme};
use tucker_lite::tensor::datasets;

fn small(name: &str) -> Workload {
    let spec = datasets::by_name(name).unwrap().scaled(0.04);
    Workload::from_spec(&spec, 1.0)
}

fn run(w: &Workload, s: &dyn Scheme, p: usize, k: usize) -> tucker_lite::coordinator::RunRecord {
    run_scheme(w, s, p, k, 1, &Engine::Native, NetModel::default(), 3)
}

#[test]
fn all_schemes_complete_on_3d_and_4d() {
    for name in ["nell2", "enron"] {
        let w = small(name);
        for scheme in sched::all_schemes() {
            let rec = run(&w, scheme.as_ref(), 4, 4);
            assert!(rec.hooi_secs > 0.0, "{name}/{}", rec.scheme);
            assert!(rec.fit.is_finite());
            assert!((0.0..=1.0).contains(&rec.fit), "{name}/{} fit {}", rec.scheme, rec.fit);
        }
    }
}

#[test]
fn coarseg_has_optimal_svd_load_lite_near_optimal() {
    // The structural claim behind Fig 12(b).
    let w = small("nell1");
    let rc = run(&w, &sched::CoarseG::default(), 6, 4);
    let rl = run(&w, &sched::Lite, 6, 4);
    assert!((rc.svd_load_norm - 1.0).abs() < 1e-9);
    assert!(rl.svd_load_norm <= 1.25, "Lite redundancy {}", rl.svd_load_norm);
}

#[test]
fn lite_ttm_balance_is_perfect_coarseg_poor_on_skewed() {
    // The structural claim behind Fig 12(a): on a skewed tensor CoarseG's
    // giant slices destroy TTM balance, Lite's hard limit preserves it.
    let w = small("enron");
    let rl = run(&w, &sched::Lite, 8, 4);
    let rc = run(&w, &sched::CoarseG::default(), 8, 4);
    assert!(rl.ttm_balance <= 1.01, "Lite balance {}", rl.ttm_balance);
    assert!(
        rc.ttm_balance > rl.ttm_balance,
        "CoarseG {} should trail Lite {}",
        rc.ttm_balance,
        rl.ttm_balance
    );
}

#[test]
fn multi_policy_fm_volume_exceeds_uni_policy_svd_tradeoff() {
    // Fig 13's shape: Lite/CoarseG (multi-policy) pay FM volume but save
    // SVD volume; MediumG pays SVD volume.
    let w = small("nell1");
    let rl = run(&w, &sched::Lite, 8, 4);
    let rm = run(&w, &sched::MediumG, 8, 4);
    assert!(
        rl.svd_volume < rm.svd_volume,
        "Lite SVD vol {} should be < MediumG {}",
        rl.svd_volume,
        rm.svd_volume
    );
}

#[test]
fn same_seed_same_record() {
    let w = small("flickr");
    let a = run(&w, &sched::Lite, 4, 4);
    let b = run(&w, &sched::Lite, 4, 4);
    assert_eq!(a.svd_volume, b.svd_volume);
    assert_eq!(a.fm_volume, b.fm_volume);
    assert!((a.fit - b.fit).abs() < 1e-9);
}

#[test]
fn more_ranks_do_not_increase_hooi_time_under_lite() {
    // strong-scaling sanity on a medium analogue (Fig 15's premise);
    // needs a compute-dominated size — K=10 and a quarter-scale tensor
    let spec = datasets::by_name("nell1").unwrap().scaled(0.25);
    let w = Workload::from_spec(&spec, 1.0);
    let r8 = run(&w, &sched::Lite, 8, 10);
    let r32 = run(&w, &sched::Lite, 32, 10);
    assert!(
        r32.hooi_secs < r8.hooi_secs,
        "P=32 {} should beat P=8 {}",
        r32.hooi_secs,
        r8.hooi_secs
    );
}

#[test]
fn tns_file_pipeline() {
    // write a .tns, load as workload, decompose
    use tucker_lite::tensor::{io, SparseTensor};
    use tucker_lite::util::rng::Rng;
    let mut rng = Rng::new(11);
    let t = SparseTensor::random(vec![20, 16, 12], 800, &mut rng);
    let dir = std::env::temp_dir().join("tucker_lite_pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pipe.tns");
    io::write_tns(&t, &path).unwrap();
    let w = Workload::from_tns(&path).unwrap();
    assert_eq!(w.tensor.nnz(), 800);
    let rec = run(&w, &sched::Lite, 4, 4);
    assert!(rec.fit.is_finite());
}

#[test]
fn distribution_time_lightweight_vs_hyperg_ordering() {
    // Fig 16's headline: HyperG distribution is orders of magnitude
    // slower than the lightweight schemes.
    let w = small("nell2");
    use tucker_lite::util::rng::Rng;
    let mut lite_t = 0.0;
    let mut hyper_t = 0.0;
    for scheme in sched::all_schemes() {
        let mut rng = Rng::new(5);
        let d = scheme.policies(&w.tensor, &w.idx, 8, &mut rng);
        match scheme.name() {
            "Lite" => lite_t = d.time.simulated_secs,
            "HyperG" => hyper_t = d.time.simulated_secs,
            _ => {}
        }
    }
    assert!(
        hyper_t > 5.0 * lite_t,
        "HyperG {hyper_t} should be >> Lite {lite_t}"
    );
}
