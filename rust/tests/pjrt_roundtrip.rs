//! Integration: the AOT HLO artifacts round-trip through the PJRT CPU
//! client and agree numerically with the native reference engine — the
//! rust-side counterpart of python/tests/test_kernel.py.
//!
//! These tests skip (with a note) when `make artifacts` has not run.

use tucker_lite::linalg::Mat;
use tucker_lite::runtime::{Engine, PjrtRuntime, Registry};
use tucker_lite::util::rng::Rng;

fn pjrt() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let reg = Registry::load(&dir).expect("manifest parses");
    Some(Engine::Pjrt(PjrtRuntime::new(reg).expect("pjrt client")))
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

#[test]
fn kron3_pjrt_matches_native() {
    let Some(engine) = pjrt() else { return };
    let k = 10;
    let b = engine.ttm_batch_size(3, k);
    let mut rng = Rng::new(1);
    let rows_a = rand_vec(&mut rng, b * k);
    let rows_b = rand_vec(&mut rng, b * k);
    let vals = rand_vec(&mut rng, b);
    let got = engine.kron3_batch(k, &rows_a, &rows_b, &vals);
    let want = Engine::Native.kron3_batch(k, &rows_a, &rows_b, &vals);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-4, "idx {i}: {g} vs {w}");
    }
}

#[test]
fn kron3_k20_pjrt_matches_native() {
    let Some(engine) = pjrt() else { return };
    let k = 20;
    let b = engine.ttm_batch_size(3, k);
    let mut rng = Rng::new(2);
    let rows_a = rand_vec(&mut rng, b * k);
    let rows_b = rand_vec(&mut rng, b * k);
    let vals = rand_vec(&mut rng, b);
    let got = engine.kron3_batch(k, &rows_a, &rows_b, &vals);
    let want = Engine::Native.kron3_batch(k, &rows_a, &rows_b, &vals);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 2e-4);
    }
}

#[test]
fn kron4_pjrt_matches_native() {
    let Some(engine) = pjrt() else { return };
    let k = 10;
    let b = engine.ttm_batch_size(4, k);
    let mut rng = Rng::new(3);
    let rows_a = rand_vec(&mut rng, b * k);
    let rows_b = rand_vec(&mut rng, b * k);
    let rows_c = rand_vec(&mut rng, b * k);
    let vals = rand_vec(&mut rng, b);
    let got = engine.kron4_batch(k, &rows_a, &rows_b, &rows_c, &vals);
    let want = Engine::Native.kron4_batch(k, &rows_a, &rows_b, &rows_c, &vals);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 2e-4);
    }
}

#[test]
fn matvec_tiles_match_native_with_ragged_rows() {
    let Some(engine) = pjrt() else { return };
    let khat = 100;
    let mut rng = Rng::new(4);
    // rows deliberately not a multiple of R_TILE: exercises tail padding
    for rows in [1usize, 7, 511, 513, 1300] {
        let z = Mat::from_fn(rows, khat, |_, _| rng.normal() as f32);
        let x = rand_vec(&mut rng, khat);
        let got = engine.local_matvec(&z, &x);
        let want = z.matvec(&x);
        assert_eq!(got.len(), rows);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "rows={rows}");
        }
        let y = rand_vec(&mut rng, rows);
        let got = engine.local_rmatvec(&y, &z);
        let want = z.tmatvec(&y);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "rows={rows}");
        }
    }
}

#[test]
fn full_hooi_pjrt_matches_native_fit() {
    // End-to-end: the same decomposition through both engines must agree
    // on fit and factors (same seeds ⇒ same Lanczos trajectory up to
    // engine numerics).
    let Some(engine) = pjrt() else { return };
    use tucker_lite::coordinator::{run_scheme, Workload};
    use tucker_lite::dist::NetModel;
    use tucker_lite::sched::Lite;
    use tucker_lite::tensor::datasets;

    let spec = datasets::by_name("nell2").unwrap().scaled(0.05);
    let w = Workload::from_spec(&spec, 1.0);
    let rec_p = run_scheme(&w, &Lite, 4, 10, 1, &engine, NetModel::default(), 7);
    let rec_n = run_scheme(&w, &Lite, 4, 10, 1, &Engine::Native, NetModel::default(), 7);
    assert!(
        (rec_p.fit - rec_n.fit).abs() < 1e-3,
        "fit mismatch: pjrt {} vs native {}",
        rec_p.fit,
        rec_n.fit
    );
    // identical distribution ⇒ identical volumes
    assert_eq!(rec_p.svd_volume, rec_n.svd_volume);
    assert_eq!(rec_p.fm_volume, rec_n.fm_volume);
}
