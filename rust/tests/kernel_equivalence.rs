//! Kernel-layer equivalence properties: every tiled microkernel
//! (portable, and AVX2/NEON where the host supports them) must match the
//! scalar oracle within 1e-5 *relative* error on random 3-D/4-D plans —
//! including rows shorter than one lane, ranks with no elements at all,
//! and lane-padded runs, whose padding slots must never contribute to Z.

use tucker_lite::hooi::{
    assemble_local_z_fused, pad_to_lanes, Kernel, PlanWorkspace, TtmPlan, LANES,
};
use tucker_lite::linalg::{orthonormal_random, Mat};
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::rng::Rng;

fn random_factors(t: &SparseTensor, k: usize, rng: &mut Rng) -> Vec<Mat> {
    t.dims
        .iter()
        .map(|&l| orthonormal_random(l as usize, k, rng))
        .collect()
}

fn random_partition(nnz: usize, p: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    let mut out = vec![Vec::new(); p];
    for e in 0..nnz as u32 {
        out[rng.usize_below(p)].push(e);
    }
    out
}

/// Per-element relative comparison: |a−b| ≤ tol·(1 + max(|a|, |b|)).
fn assert_rel_close(a: &Mat, b: &Mat, tol: f32, ctx: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
    for (i, (&x, &y)) in a.data.iter().zip(&b.data).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{ctx}: entry {i}: {x} vs {y}"
        );
    }
}

/// Every (mode, rank) plan: tiled assembly under `kernel` must match the
/// scalar oracle (and the element-order oracle) on the same plan.
fn check_kernel_case(
    kernel: Kernel,
    dims: Vec<u32>,
    nnz: usize,
    k: usize,
    p: usize,
    seed: u64,
) {
    let mut rng = Rng::new(seed);
    let t = SparseTensor::random(dims, nnz, &mut rng);
    let factors = random_factors(&t, k, &mut rng);
    let per_rank = random_partition(t.nnz(), p, &mut rng);
    let mut ws_scalar = PlanWorkspace::with_kernel(Kernel::Scalar);
    let mut ws_tiled = PlanWorkspace::with_kernel(kernel);
    for mode in 0..t.ndim() {
        for elems in &per_rank {
            let plan = TtmPlan::build(&t, mode, elems, k);
            let want = plan.assemble_fused(&factors, &mut ws_scalar);
            let got = plan.assemble_fused(&factors, &mut ws_tiled);
            assert_eq!(got.rows, want.rows, "mode {mode} rows");
            assert_rel_close(
                &got.z,
                &want.z,
                1e-5,
                &format!("kernel {} mode {mode}", kernel.name()),
            );
            // and both agree with the element-order oracle (coarser
            // tolerance: different summation order)
            let oracle = assemble_local_z_fused(&t, mode, elems, &factors);
            assert_eq!(got.rows, oracle.rows);
            assert!(got.z.max_abs_diff(&oracle.z) < 1e-4, "mode {mode} vs oracle");
            ws_scalar.recycle(want.z);
            ws_tiled.recycle(got.z);
        }
    }
}

/// The tiled kernels the host can actually run (portable always; AVX2 /
/// NEON only where detection succeeds).
fn tiled_kernels() -> Vec<Kernel> {
    [Kernel::Portable, Kernel::Avx2, Kernel::Neon]
        .into_iter()
        .filter(|k| k.available())
        .collect()
}

#[test]
fn tiled_kernels_match_scalar_on_random_3d_plans() {
    // Miri interprets every load/store, so the sweep shrinks to one
    // small case there — the point under Miri is UB detection in the
    // tile loops, not statistical coverage (CI runs the full sweep
    // natively as well)
    let cases: &[(usize, usize, usize)] = if cfg!(miri) {
        &[(120, 2, 3)]
    } else {
        &[(900, 4, 5), (350, 7, 3), (1200, 2, 16)]
    };
    for kernel in tiled_kernels() {
        for (seed, &(nnz, p, k)) in cases.iter().enumerate() {
            check_kernel_case(kernel, vec![20, 14, 9], nnz, k, p, seed as u64 + 1);
        }
    }
}

#[test]
fn tiled_kernels_match_scalar_on_random_4d_plans() {
    let cases: &[(usize, usize, usize)] =
        if cfg!(miri) { &[(90, 2, 3)] } else { &[(700, 3, 3), (250, 5, 10)] };
    for kernel in tiled_kernels() {
        for (seed, &(nnz, p, k)) in cases.iter().enumerate() {
            check_kernel_case(kernel, vec![10, 8, 6, 5], nnz, k, p, seed as u64 + 10);
        }
    }
}

#[test]
fn rows_shorter_than_one_lane() {
    // nnz ≪ rows·cols: almost every run is a single element, so every
    // run is pure padding beyond slot 0 — K both below and above LANES
    for kernel in tiled_kernels() {
        check_kernel_case(kernel, vec![40, 6, 5], 25, 3, 2, 77);
        check_kernel_case(kernel, vec![40, 6, 5], 25, 16, 2, 78);
        check_kernel_case(kernel, vec![12, 5, 4, 3], 15, 4, 2, 79);
    }
}

#[test]
fn empty_ranks_yield_empty_locals_under_every_kernel() {
    let mut rng = Rng::new(5);
    let t = SparseTensor::random(vec![9, 9, 9], 120, &mut rng);
    let factors = random_factors(&t, 4, &mut rng);
    for kernel in [Kernel::Scalar, Kernel::Portable, Kernel::Avx2, Kernel::Neon] {
        let mut ws = PlanWorkspace::with_kernel(kernel);
        let plan = TtmPlan::build(&t, 1, &[], 4);
        let local = plan.assemble_fused(&factors, &mut ws);
        assert!(local.rows.is_empty());
        assert_eq!(local.z.rows, 0);
        assert_eq!(local.z.cols, 16);
    }
}

#[test]
fn padded_lanes_never_contribute_to_z() {
    let mut rng = Rng::new(42);
    let nnz = if cfg!(miri) { 150 } else { 300 };
    let t = SparseTensor::random(vec![25, 10, 6], nnz, &mut rng);
    let factors = random_factors(&t, 5, &mut rng);
    let elems: Vec<u32> = (0..t.nnz() as u32).collect();
    for mode in 0..3 {
        let plan = TtmPlan::build(&t, mode, &elems, 5);
        // short slow dimensions force plenty of sub-lane runs
        assert!(
            plan.padded_slots() > plan.nnz(),
            "mode {mode}: case must actually exercise lane padding"
        );
        // builder invariant: every padded slot is exactly val == 0.0 and
        // repeats an in-bounds factor row
        let nfast = factors[plan.others[0]].rows as u32;
        for j in 0..plan.run_b.len() {
            let (lo, hi) = (plan.slot_ptr[j] as usize, plan.slot_ptr[j + 1] as usize);
            let len = plan.run_len[j] as usize;
            assert_eq!(hi - lo, pad_to_lanes(len));
            for s in lo + len..hi {
                assert_eq!(plan.vals[s].to_bits(), 0.0f32.to_bits());
                assert!(plan.fa[s] < nfast);
            }
        }
        assert_eq!(plan.padded_slots() % LANES, 0);
        // and the assembled Z equals the element-order oracle, which
        // never saw the padding at all
        for kernel in tiled_kernels() {
            let mut ws = PlanWorkspace::with_kernel(kernel);
            let got = plan.assemble_fused(&factors, &mut ws);
            let oracle = assemble_local_z_fused(&t, mode, &elems, &factors);
            assert_eq!(got.rows, oracle.rows);
            assert!(
                got.z.max_abs_diff(&oracle.z) < 1e-4,
                "mode {mode} kernel {}",
                kernel.name()
            );
        }
    }
}

#[test]
fn workspace_carries_its_pinned_kernel() {
    // (detection/resolution rules themselves are covered by the kernel
    // module's unit tests)
    let ws = PlanWorkspace::with_kernel(Kernel::Scalar);
    assert_eq!(ws.kernel(), Kernel::Scalar);
    assert!(PlanWorkspace::new().kernel().available());
}
