//! Shared-CSF plan layer integration: `PlanChoice::SharedCsf` must be
//! **bit-identical** to `PlanChoice::PerMode` on every decomposition
//! (3-D property-tested, 4-D pinned across executors and kernels), the
//! per-rank trees keep the CSF structural invariants through ingest
//! splices and rebalance migrations, ingest + decompose under the
//! shared layout matches a fresh shared build on the mutated tensor,
//! and crash recovery lands the same bits regardless of the plan
//! layout.

use tucker_lite::coordinator::{
    ExecutorChoice, KernelChoice, PlanChoice, SchemeChoice, TuckerSession,
    Workload,
};
use tucker_lite::dist::FaultPlan;
use tucker_lite::hooi::{check_csf_invariants, CoreRanks, Kernel};
use tucker_lite::prop_assert;
use tucker_lite::sched::{Distribution, Scheme};
use tucker_lite::tensor::{SliceIndex, SparseTensor, TensorDelta};
use tucker_lite::util::check::Runner;
use tucker_lite::util::rng::Rng;

/// A scheme that replays a fixed distribution — pins "the same
/// placement" when comparing a streamed shared session against a fresh
/// build on the mutated tensor.
struct Fixed(Distribution);

impl Scheme for Fixed {
    fn name(&self) -> &'static str {
        "Fixed"
    }

    fn uni(&self) -> bool {
        self.0.uni
    }

    fn policies(
        &self,
        _t: &SparseTensor,
        _idx: &[SliceIndex],
        _p: usize,
        _rng: &mut Rng,
    ) -> Distribution {
        self.0.clone()
    }
}

fn workload(dims: Vec<u32>, nnz: usize, seed: u64) -> Workload {
    let mut rng = Rng::new(seed);
    Workload::from_tensor("csf", SparseTensor::random(dims, nnz, &mut rng))
}

fn build(
    w: &Workload,
    scheme: SchemeChoice,
    p: usize,
    k: usize,
    invocations: usize,
    plan: PlanChoice,
) -> TuckerSession {
    TuckerSession::builder(w.clone())
        .scheme(scheme)
        .ranks(p)
        .core(CoreRanks::Uniform(k))
        .invocations(invocations)
        .plan(plan)
        .seed(31)
        .build()
        .expect("valid session")
}

fn random_delta(
    t: &SparseTensor,
    rng: &mut Rng,
    n_app: usize,
    n_chg: usize,
    n_rem: usize,
) -> TensorDelta {
    let mut d = TensorDelta::new();
    for _ in 0..n_app {
        let coord: Vec<u32> =
            t.dims.iter().map(|&l| rng.below(l as u64) as u32).collect();
        d = d.append(&coord, rng.f32() * 2.0 - 1.0);
    }
    let existing = |rng: &mut Rng| -> Vec<u32> {
        let e = rng.usize_below(t.nnz());
        (0..t.ndim()).map(|m| t.coord(m, e)).collect()
    };
    for _ in 0..n_chg {
        let coord = existing(rng);
        d = d.change(&coord, rng.f32() * 2.0 - 1.0);
    }
    for _ in 0..n_rem {
        let coord = existing(rng);
        d = d.remove(&coord);
    }
    d
}

/// Every per-rank tree of a shared-layout session passes the CSF
/// structural invariants against the live mode element lists.
fn assert_shared_invariants(s: &TuckerSession) {
    let t = &s.workload().tensor;
    let shared = s.shared_plans().expect("SharedCsf layout");
    assert_eq!(shared.per_rank.len(), s.distribution().p);
    for (rank, plan) in shared.per_rank.iter().enumerate() {
        let lists: Vec<&[u32]> = s
            .mode_states()
            .iter()
            .map(|st| st.elems[rank].as_slice())
            .collect();
        check_csf_invariants(t, plan, &lists);
    }
}

fn assert_bit_identical(
    a: &tucker_lite::coordinator::Decomposition,
    b: &tucker_lite::coordinator::Decomposition,
    ctx: &str,
) {
    assert_eq!(a.fit().to_bits(), b.fit().to_bits(), "{ctx}: fit diverges");
    for (n, (x, y)) in a.factors.iter().zip(&b.factors).enumerate() {
        assert_eq!(x.data, y.data, "{ctx}: mode {n} factors diverge");
    }
    assert_eq!(a.core.data, b.core.data, "{ctx}: cores diverge");
}

#[test]
fn shared_matches_per_mode_bit_exactly_3d() {
    Runner::new(10, 30).run("csf-shared-per-mode-equivalence", |case, rng| {
        let p = 2 + rng.usize_below(4);
        let k = 2 + rng.usize_below(3);
        let dims = vec![
            (8 + rng.usize_below(case.size + 8)) as u32,
            (6 + rng.usize_below(12)) as u32,
            (4 + rng.usize_below(8)) as u32,
        ];
        let nnz = 150 + rng.usize_below(case.size * 10 + 50);
        let w = Workload::from_tensor("csf", SparseTensor::random(dims, nnz, rng));
        // alternate uni (MediumG: views exist) and non-uni (Lite:
        // all-Stream degradation) schemes — both must be bit-exact
        let scheme = || {
            if case.index % 2 == 0 {
                SchemeChoice::Lite
            } else {
                SchemeChoice::MediumG
            }
        };
        let mut a = build(&w, scheme(), p, k, 2, PlanChoice::PerMode);
        let mut b = build(&w, scheme(), p, k, 2, PlanChoice::SharedCsf);
        prop_assert!(a.shared_plans().is_none(), "per-mode holds no trees");
        prop_assert!(
            b.shared_plans().map_or(0, |sp| sp.per_rank.len()) == p,
            "one tree per rank"
        );
        let da = a.decompose();
        let db = b.decompose();
        prop_assert!(
            da.fit().to_bits() == db.fit().to_bits(),
            "fit {} vs shared {}",
            da.fit(),
            db.fit()
        );
        for (n, (x, y)) in da.factors.iter().zip(&db.factors).enumerate() {
            prop_assert!(x.data == y.data, "mode {n} factors diverge");
        }
        prop_assert!(da.core.data == db.core.data, "cores diverge");
        Ok(())
    });
}

#[test]
fn shared_matches_per_mode_across_executors_and_kernels_4d() {
    let w = workload(vec![10, 8, 6, 5], 400, 17);
    for executor in [ExecutorChoice::Serial, ExecutorChoice::Parallel] {
        for kernel in [Kernel::Scalar, Kernel::Portable] {
            let run = |plan: PlanChoice| {
                TuckerSession::builder(w.clone())
                    .scheme(SchemeChoice::Lite)
                    .ranks(3)
                    .core(CoreRanks::Uniform(3))
                    .invocations(2)
                    .executor(executor)
                    .kernel(KernelChoice::Fixed(kernel))
                    .plan(plan)
                    .seed(23)
                    .build()
                    .unwrap()
                    .decompose()
            };
            let a = run(PlanChoice::PerMode);
            let b = run(PlanChoice::SharedCsf);
            assert_bit_identical(&a, &b, &format!("{executor:?}/{kernel:?}"));
        }
    }
}

#[test]
fn session_trees_keep_invariants_through_consecutive_ingests() {
    Runner::new(8, 25).run("csf-ingest-invariants", |case, rng| {
        let p = 2 + rng.usize_below(3);
        let dims = vec![
            (6 + rng.usize_below(case.size + 6)) as u32,
            (5 + rng.usize_below(10)) as u32,
            (4 + rng.usize_below(6)) as u32,
        ];
        let nnz = 120 + rng.usize_below(case.size * 8 + 40);
        let w = Workload::from_tensor("csf", SparseTensor::random(dims, nnz, rng));
        let mut s = build(&w, SchemeChoice::Lite, p, 3, 1, PlanChoice::SharedCsf);
        assert_shared_invariants(&s);
        // consecutive ingests stress splice-on-spliced trees
        for round in 0..3 {
            let n_app = 1 + rng.usize_below(12);
            let n_chg = rng.usize_below(6);
            let n_rem = rng.usize_below(3);
            let delta =
                random_delta(&s.workload().tensor, rng, n_app, n_chg, n_rem);
            let rep =
                s.ingest(&delta).map_err(|e| format!("round {round}: {e}"))?;
            prop_assert!(
                rep.plan_count == p,
                "shared layout reports one tree per rank, got {}",
                rep.plan_count
            );
            prop_assert!(
                rep.plans_touched() <= rep.plan_count,
                "touched {} of {} trees",
                rep.plans_touched(),
                rep.plan_count
            );
            assert_shared_invariants(&s);
        }
        Ok(())
    });
}

#[test]
fn shared_ingest_matches_fresh_shared_build() {
    let mut rng = Rng::new(43);
    let t = SparseTensor::random(vec![18, 14, 9], 700, &mut rng);
    let w = Workload::from_tensor("csf", t);
    let mut streamed = build(&w, SchemeChoice::Lite, 4, 3, 1, PlanChoice::SharedCsf);
    let delta = random_delta(&streamed.workload().tensor, &mut rng, 25, 6, 3);
    streamed.ingest(&delta).unwrap();
    assert_shared_invariants(&streamed);
    let w2 = Workload::from_tensor("fresh", streamed.workload().tensor.clone());
    let mut fresh = build(
        &w2,
        SchemeChoice::custom(Box::new(Fixed(streamed.distribution().clone()))),
        4,
        3,
        1,
        PlanChoice::SharedCsf,
    );
    let d_inc = streamed.decompose();
    let d_fresh = fresh.decompose();
    assert_bit_identical(&d_inc, &d_fresh, "ingest vs fresh shared build");
    assert_eq!(streamed.plan_builds(), 1, "ingest never re-runs prepare_modes");
}

#[test]
fn value_only_ingest_splices_shared_trees_in_place() {
    let mut rng = Rng::new(23);
    let t = SparseTensor::random(vec![20, 15, 10], 900, &mut rng);
    let w = Workload::from_tensor("values", t);
    let mut s = build(&w, SchemeChoice::Lite, 4, 4, 1, PlanChoice::SharedCsf);
    let delta = random_delta(&s.workload().tensor, &mut rng, 0, 5, 2);
    let rep = s.ingest(&delta).unwrap();
    assert_eq!(rep.appended, 0);
    assert!(rep.plans_rebuilt == 0, "small value batches splice in place");
    assert!(rep.plans_spliced >= 1);
    assert_shared_invariants(&s);
    let w2 = Workload::from_tensor("fresh", s.workload().tensor.clone());
    let mut fresh = build(
        &w2,
        SchemeChoice::custom(Box::new(Fixed(s.distribution().clone()))),
        4,
        4,
        1,
        PlanChoice::SharedCsf,
    );
    let d_inc = s.decompose();
    let d_fresh = fresh.decompose();
    assert_bit_identical(&d_inc, &d_fresh, "value splice vs fresh shared build");
}

#[test]
fn rebalance_migration_round_trip_under_shared() {
    let mut rng = Rng::new(19);
    let t = SparseTensor::random(vec![10, 8, 6, 5], 500, &mut rng);
    let w = Workload::from_tensor("csf4d", t);
    let mut streamed = build(&w, SchemeChoice::Lite, 3, 3, 1, PlanChoice::SharedCsf);
    let delta = random_delta(&streamed.workload().tensor, &mut rng, 30, 0, 0);
    streamed.ingest(&delta).unwrap();
    let rb = streamed.rebalance();
    assert!(rb.migrated, "a fresh Lite re-plan of a grown tensor moves elements");
    assert!(
        rb.plans_spliced + rb.plans_rebuilt <= 3,
        "at most one rebuild per rank's tree, got {}",
        rb.plans_spliced + rb.plans_rebuilt
    );
    assert_shared_invariants(&streamed);
    let w2 = Workload::from_tensor("fresh", streamed.workload().tensor.clone());
    let mut fresh = build(
        &w2,
        SchemeChoice::custom(Box::new(Fixed(streamed.distribution().clone()))),
        3,
        3,
        1,
        PlanChoice::SharedCsf,
    );
    let d_inc = streamed.decompose();
    let d_fresh = fresh.decompose();
    assert_bit_identical(&d_inc, &d_fresh, "migration vs fresh shared build");
    assert_eq!(streamed.plan_builds(), 1, "migration never re-runs prepare_modes");
}

#[test]
fn crash_recovery_is_plan_layout_invariant() {
    // a crash at a mid-sweep phase recovers via survivor re-placement;
    // the recovered bits must not depend on the plan layout, and the
    // shared session's trees must reflect the post-eviction element
    // lists
    let w = workload(vec![14, 10, 8], 250, 5);
    let run = |plan: PlanChoice| {
        let mut s = TuckerSession::builder(w.clone())
            .ranks(4)
            .core(CoreRanks::Uniform(2))
            .invocations(2)
            .fault_plan(FaultPlan::new().crash_at(1, 1, 2))
            .plan(plan)
            .seed(17)
            .build()
            .unwrap();
        let d = s.try_decompose().expect("recovers");
        assert_eq!(s.dead_ranks(), vec![2]);
        assert_eq!(d.record.faults_injected, 1);
        (s, d)
    };
    let (_a, da) = run(PlanChoice::PerMode);
    let (b, db) = run(PlanChoice::SharedCsf);
    assert_bit_identical(&da, &db, "recovery across plan layouts");
    assert_shared_invariants(&b);
    // the dead rank's tree is empty after survivor re-placement
    let shared = b.shared_plans().unwrap();
    assert_eq!(shared.per_rank[2].spine.nnz(), 0, "victim owns nothing");
}

#[test]
fn checkpoint_restore_round_trip_under_shared() {
    let w = workload(vec![15, 12, 9], 300, 6);
    let mk = || build(&w, SchemeChoice::Lite, 4, 3, 2, PlanChoice::SharedCsf);
    let mut original = mk();
    original.decompose();
    let cp = original.checkpoint().expect("state to checkpoint");
    let wire = tucker_lite::coordinator::SessionCheckpoint::parse(&cp.serialize())
        .expect("parses");
    let mut resumed = mk();
    resumed.restore(&wire).expect("restores");
    let a = original.decompose_more(1);
    let b = resumed.decompose_more(1);
    assert_bit_identical(&a, &b, "checkpoint round trip under shared");
    assert_shared_invariants(&resumed);
}
