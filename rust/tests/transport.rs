//! Transport seam end-to-end: the channel transport's *measured* byte
//! volumes must match the α–β `NetModel`'s unit accounting exactly
//! (p2p) / to rounding (allreduce); corrupted frames retransmit
//! transparently inside the retry budget and surface as a transient
//! failure past it; a really hung rank — no `FaultPlan` involvement —
//! is detected by the heartbeat/deadline monitor, classified as a
//! crash, and recovered **bit-identically** to the equivalent injected
//! crash; and full sessions land the same bits under both transports
//! while `RunRecord::net_model_error` reports the prediction gap.

use tucker_lite::coordinator::{TuckerSession, TuckerSessionBuilder, Workload};
use tucker_lite::dist::{
    ChannelTransport, FailureKind, FaultPlan, NetModel, Transport, TransportChoice,
    TransportTuning,
};
use tucker_lite::hooi::CoreRanks;
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::rng::Rng;
use tucker_lite::util::float::exactly_zero_f64;

fn workload(dims: Vec<u32>, nnz: usize, seed: u64) -> Workload {
    let mut rng = Rng::new(seed);
    Workload::from_tensor("transport", SparseTensor::random(dims, nnz, &mut rng))
}

fn builder(w: &Workload, p: usize, k: usize, sweeps: usize) -> TuckerSessionBuilder {
    TuckerSession::builder(w.clone())
        .ranks(p)
        .core(CoreRanks::Uniform(k))
        .invocations(sweeps)
        .seed(17)
}

/// Deadline tight enough that a wedged peer is detected fast, but far
/// above the microseconds a healthy in-process exchange takes.
fn tight(deadline: f64) -> TransportTuning {
    TransportTuning { phase_deadline: deadline, ..TransportTuning::default() }
}

/// Property: the channel p2p moves *exactly* the units `NetModel::p2p_volume`
/// accounts — the frames are real, so the measurement is a count, not a model.
#[test]
fn channel_p2p_units_match_net_model_volume_exactly() {
    let net = NetModel::default();
    let configs: Vec<Vec<(u64, u64)>> = vec![
        vec![(2, 100), (1, 50), (3, 10), (1, 0)],
        vec![(1, 7), (2, 9)],
        vec![(1, 1), (0, 0), (4, 33)],
        vec![(3, 17), (1, 5), (2, 12), (1, 8), (1, 3)],
    ];
    let mut expected_total = 0u64;
    let mut t = ChannelTransport::new(8, TransportTuning::default());
    for per_rank in &configs {
        let m = t.p2p(&net, per_rank).expect("healthy exchange");
        let vol = net.p2p_volume(per_rank);
        assert_eq!(m.units, vol as f64, "per_rank {per_rank:?}");
        assert!(m.secs > 0.0, "real wall time was spent");
        expected_total += vol;
    }
    let stats = t.stats();
    assert_eq!(stats.p2p_ops, configs.len() as u64);
    assert_eq!(stats.payload_units, expected_total);
    assert_eq!(stats.frames_retried, 0);
    // headers cost 24 bytes per frame on top of 4 bytes per unit
    assert_eq!(
        stats.bytes_moved,
        4 * stats.payload_units + 24 * stats.frames_sent
    );
}

/// Property: the channel ring allreduce delivers `2(P−1)·u` units in
/// total, i.e. `NetModel::allreduce_volume`'s `2(P−1)/P·u` per rank (to
/// f64 rounding — the two divide in different orders).
#[test]
fn channel_allreduce_units_match_net_model_volume() {
    let net = NetModel::default();
    for p in [2usize, 3, 4, 8] {
        for units in [1u64, 5, 64, 1000] {
            let mut t = ChannelTransport::new(p, TransportTuning::default());
            let m = t.allreduce(&net, p, units).expect("healthy allreduce");
            let want = net.allreduce_volume(p, units);
            assert!(
                (m.units - want).abs() <= 1e-9 * want.max(1.0),
                "p {p} units {units}: measured {} predicted {want}",
                m.units
            );
            // the raw wire count is exact: 2(P−1) ring steps of u/P each
            assert_eq!(
                t.stats().payload_units,
                2 * (p as u64 - 1) * units,
                "p {p} units {units}"
            );
        }
    }
}

/// A corrupted frame is nacked, retransmitted once, and the collective
/// still completes with exact unit accounting — corruption inside the
/// retry budget is invisible to the caller.
#[test]
fn corrupted_frame_retries_transparently() {
    let net = NetModel::default();
    let mut t = ChannelTransport::new(3, TransportTuning::default());
    t.corrupt_next_frames(1);
    let per_rank = [(2u64, 10u64), (1, 5), (1, 3)];
    let m = t.p2p(&net, &per_rank).expect("retry absorbs the corruption");
    assert_eq!(m.units, net.p2p_volume(&per_rank) as f64);
    let stats = t.stats();
    assert_eq!(stats.frames_retried, 1, "exactly one retransmission");
    assert_eq!(stats.frames_sent, 4 + 1, "4 frames + 1 retransmit");
}

/// Corruption persisting past `max_retries` surfaces as a transient
/// failure blaming the affected link — and the *next* collective on the
/// same transport (budget exhausted) completes cleanly: the failure
/// really was transient.
#[test]
fn corruption_past_retry_budget_is_a_transient_failure() {
    let net = NetModel::default();
    let tuning = TransportTuning { max_retries: 2, ..TransportTuning::default() };
    let mut t = ChannelTransport::new(2, tuning);
    // one frame in flight total, so all 3 corruptions hit the same frame:
    // original + 2 retransmissions all fail verification → budget spent
    t.corrupt_next_frames(3);
    let per_rank = [(1u64, 8u64), (0, 0)];
    let f = t.p2p(&net, &per_rank).expect_err("retry budget exhausted");
    assert_eq!(f.kind, FailureKind::Transient, "{}", f.detail);
    assert!(f.detail.contains("checksum"), "{}", f.detail);
    assert_eq!(t.stats().frames_retried, 2);
    // clean retry of the whole collective succeeds
    let m = t.p2p(&net, &per_rank).expect("clean retry");
    assert_eq!(m.units, 8.0);
}

/// A wedged (silently hung, never heartbeating) rank is detected by the
/// phase deadline and classified as a crash; after `mark_dead` the
/// survivors exchange without it.
#[test]
fn wedged_rank_is_detected_as_a_crash_and_survivors_continue() {
    let net = NetModel::default();
    let mut t = ChannelTransport::new(3, tight(0.05));
    t.wedge_rank(1);
    let per_rank = [(1u64, 4u64), (1, 4), (1, 4)];
    let f = t.p2p(&net, &per_rank).expect_err("hung peer detected");
    assert_eq!(f.rank, 1, "{}", f.detail);
    assert_eq!(f.kind, FailureKind::Crash, "{}", f.detail);
    // evict the hung rank: the survivor ring completes
    t.mark_dead(1);
    let survivors = [(1u64, 4u64), (0, 0), (1, 4)];
    let m = t.p2p(&net, &survivors).expect("survivors exchange");
    assert_eq!(m.units, 8.0);
}

/// A rank that heartbeats but exceeds the phase deadline is classified
/// as a straggler timeout — alive is distinguishable from dead — and
/// the one-shot delay clears, so the retry completes.
#[test]
fn delayed_rank_is_a_straggler_timeout_and_retry_succeeds() {
    let net = NetModel::default();
    let mut t = ChannelTransport::new(3, tight(0.05));
    t.delay_rank_once(1, 0.25);
    let per_rank = [(1u64, 4u64), (1, 4), (1, 4)];
    let f = t.p2p(&net, &per_rank).expect_err("straggler past deadline");
    assert_eq!(f.rank, 1, "{}", f.detail);
    assert_eq!(f.kind, FailureKind::StragglerTimeout, "{}", f.detail);
    assert!(f.detail.contains("heartbeating"), "{}", f.detail);
    let m = t.p2p(&net, &per_rank).expect("delay was one-shot");
    assert_eq!(m.units, 12.0);
}

/// Tentpole bit-identity: a full session — decompose, planned eviction,
/// continue — lands the same factor/core bits whether communication is
/// analytically charged or really moved, because the predicted α–β cost
/// is what feeds the accounting under both transports. The channel run
/// additionally reports a nonzero prediction gap; the sim run's gap is
/// exactly zero by definition.
#[test]
fn sessions_are_bit_identical_across_transports() {
    let w = workload(vec![12, 10, 8], 220, 3);
    let run = |choice: TransportChoice| {
        let mut s = builder(&w, 4, 2, 2).transport(choice).build().unwrap();
        let first = s.decompose();
        s.evict_rank(1).expect("3 survivors");
        let second = s.decompose_more(1);
        (first, second)
    };
    let (sim_a, sim_b) = run(TransportChoice::Sim);
    let (ch_a, ch_b) = run(TransportChoice::Channel);
    for (x, y) in [(&sim_a, &ch_a), (&sim_b, &ch_b)] {
        for (n, (fx, fy)) in x.factors.iter().zip(&y.factors).enumerate() {
            assert_eq!(fx.data, fy.data, "mode {n} factor bits diverge");
        }
        assert_eq!(x.core.data, y.core.data, "core bits diverge");
        assert_eq!(x.record.fit.to_bits(), y.record.fit.to_bits());
        // the paper-facing accounting is transport-invariant too
        assert_eq!(x.record.hooi_secs.to_bits(), y.record.hooi_secs.to_bits());
        assert_eq!(x.record.comm_secs.to_bits(), y.record.comm_secs.to_bits());
    }
    assert_eq!(sim_a.record.transport, "sim");
    assert_eq!(ch_a.record.transport, "channel");
    // sim: measured is defined as the prediction
    assert!(!sim_a.record.net_model_error.is_empty());
    for (cat, err) in &sim_a.record.net_model_error {
        assert_eq!(*err, 0.0, "sim category {cat}");
    }
    // channel: real wall time was measured against the α–β prediction
    assert!(!ch_a.record.net_model_error.is_empty());
    assert!(ch_a.record.net_model_error.iter().all(|(_, e)| e.is_finite()));
    assert!(
        ch_a.record.net_model_error.iter().any(|(_, e)| !exactly_zero_f64(*e)),
        "a real exchange never lands exactly on the analytic prediction"
    );
}

/// Acceptance: a *real* hung rank — wedged transport endpoint, zero
/// injected faults — is detected by the heartbeat/deadline monitor,
/// classified as a crash, evicted, and the recovered decomposition is
/// bit-identical both to the equivalent `FaultPlan`-injected crash and
/// to a planned eviction at the same rollback boundary.
#[test]
fn real_hung_rank_recovers_bit_identically_to_injected_crash() {
    const VICTIM: usize = 2;
    let w = workload(vec![12, 10, 8], 220, 3);

    // planned eviction before the first sweep (the sweep-0 rollback
    // boundary is the bootstrap)
    let mut base = builder(&w, 4, 2, 2).transport(TransportChoice::Sim).build().unwrap();
    base.evict_rank(VICTIM).expect("3 survivors");
    let want = base.decompose();

    // injected crash in sweep 0 under the analytic transport
    let mut inj = builder(&w, 4, 2, 2)
        .transport(TransportChoice::Sim)
        .fault_plan(FaultPlan::new().crash_at(0, 0, VICTIM))
        .build()
        .unwrap();
    let got_inj = inj.try_decompose().expect("injected crash recovers");
    assert_eq!(inj.faults_injected(), 1);

    // the real thing: rank 2 hangs silently inside the channel transport;
    // no FaultPlan is armed anywhere
    let mut real = builder(&w, 4, 2, 2)
        .transport(TransportChoice::Channel)
        .transport_tuning(tight(0.1))
        .build()
        .unwrap();
    real.wedge_rank(VICTIM);
    let got_real = real.try_decompose().expect("real hang recovers");

    assert_eq!(real.faults_injected(), 0, "no injector involved");
    assert_eq!(real.dead_ranks(), vec![VICTIM]);
    assert!(real.recoveries() >= 1);
    assert!(real.placement().scheme().ends_with("+evict"));
    assert!(got_real.record.recovery_secs > 0.0);
    // the dead rank owns nothing after survivor re-placement
    for pol in &real.placement().dist.policies {
        assert!(pol.assign.iter().all(|&r| r != VICTIM as u32));
    }

    for other in [&got_inj, &got_real] {
        for (n, (a, b)) in want.factors.iter().zip(&other.factors).enumerate() {
            assert_eq!(a.data, b.data, "mode {n} factor bits diverge");
        }
        assert_eq!(want.core.data, other.core.data, "core bits diverge");
        assert_eq!(want.record.fit.to_bits(), other.record.fit.to_bits());
    }
}
