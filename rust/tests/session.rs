//! `TuckerSession` integration: the builder → decompose → decompose_more
//! round trip (plan reuse, bit-exact continuation) and the per-mode core
//! rank capability end-to-end (factor/core shapes, uniform equivalence,
//! fit monotonicity, ragged kp-tile plan shapes).

use tucker_lite::coordinator::{
    EngineChoice, ExecutorChoice, KernelChoice, SchemeChoice, TuckerSession, Workload,
};
use tucker_lite::hooi::{
    assemble_local_z_fused, pad_to_lanes, CoreRanks, Kernel, PlanWorkspace, TtmPlan,
};
use tucker_lite::linalg::{orthonormal_random, Mat};
use tucker_lite::runtime::Engine;
use tucker_lite::tensor::datasets;
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::rng::Rng;

fn tiny_workload() -> Workload {
    let spec = datasets::by_name("enron").unwrap().scaled(0.02);
    Workload::from_spec(&spec, 1.0)
}

/// A dense multilinear-rank-(2,2,2) tensor: fits exactly at K_n ≥ 2.
fn planted_rank2() -> Workload {
    let (lu, lv, lw) = (10usize, 9, 8);
    let mut rng = Rng::new(31);
    let mut t = SparseTensor::new(vec![lu as u32, lv as u32, lw as u32]);
    let comp: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..2)
        .map(|_| {
            (
                (0..lu).map(|_| rng.normal() as f32).collect(),
                (0..lv).map(|_| rng.normal() as f32).collect(),
                (0..lw).map(|_| rng.normal() as f32).collect(),
            )
        })
        .collect();
    for i in 0..lu {
        for j in 0..lv {
            for l in 0..lw {
                let v: f32 =
                    comp.iter().map(|(u, w, s)| u[i] * w[j] * s[l]).sum();
                t.push(&[i as u32, j as u32, l as u32], v);
            }
        }
    }
    Workload::from_tensor("planted_rank2", t)
}

#[test]
fn round_trip_reuses_plans_and_matches_fresh_run() {
    // builder → decompose() (2 invocations) → decompose_more(1): plans
    // compiled exactly once, and the result matches a fresh 3-invocation
    // session's fit within 1e-6 (the continuation is bit-exact, so the
    // tolerance is slack).
    let w = tiny_workload();
    let build = |invocations: usize| {
        TuckerSession::builder(w.clone())
            .scheme(SchemeChoice::Lite)
            .ranks(4)
            .core(CoreRanks::Uniform(4))
            .invocations(invocations)
            .seed(17)
            .build()
            .expect("valid round-trip configuration")
    };

    let mut incremental = build(2);
    let d2 = incremental.decompose();
    let d3 = incremental.decompose_more(1);
    assert_eq!(
        incremental.plan_builds(),
        1,
        "decompose_more must not re-run prepare_modes"
    );

    let mut fresh = build(3);
    let d_fresh = fresh.decompose();
    assert!(
        (d3.fit() - d_fresh.fit()).abs() < 1e-6,
        "continued {} vs fresh {}",
        d3.fit(),
        d_fresh.fit()
    );
    // factor matrices agree exactly, not just the scalar fit
    for (a, b) in d3.factors.iter().zip(&d_fresh.factors) {
        assert_eq!(a.data, b.data);
    }
    assert_eq!(d3.core.data, d_fresh.core.data);
    // the intermediate result is a genuine 2-invocation decomposition
    assert!(d2.fit().is_finite());
}

#[test]
fn per_mode_core_produces_correct_dimensions_end_to_end() {
    let w = tiny_workload();
    let mut s = TuckerSession::builder(w.clone())
        .ranks(4)
        .core(CoreRanks::PerMode(vec![3, 5, 4]))
        .seed(2)
        .build()
        .unwrap();
    let d = s.decompose();
    assert_eq!(d.core_dims(), &[3, 5, 4]);
    for (n, f) in d.factors.iter().enumerate() {
        assert_eq!(f.rows, w.tensor.dims[n] as usize, "mode {n} rows");
        assert_eq!(f.cols, [3, 5, 4][n], "mode {n} cols");
    }
    // core flattened as G_(2): K_2 × K_0·K_1
    assert_eq!(d.core.rows, 4);
    assert_eq!(d.core.cols, 15);
    assert_eq!(d.record.core, vec![3, 5, 4]);
    assert_eq!(d.record.k, 5, "record.k is the largest rank");
    assert!(d.fit().is_finite() && (0.0..=1.0).contains(&d.fit()));
    // core_at decodes the flattened layout consistently
    let mut sum_sq = 0.0f64;
    for j0 in 0..3 {
        for j1 in 0..5 {
            for j2 in 0..4 {
                sum_sq += (d.core_at(&[j0, j1, j2]) as f64).powi(2);
            }
        }
    }
    assert!((sum_sq - d.core.frob_norm().powi(2)).abs() < sum_sq.max(1.0) * 1e-4);
}

#[test]
fn per_mode_equal_ranks_match_uniform_exactly() {
    let w = tiny_workload();
    let run = |core: CoreRanks| {
        TuckerSession::builder(w.clone())
            .ranks(3)
            .core(core)
            .seed(11)
            .build()
            .unwrap()
            .decompose()
    };
    let uni = run(CoreRanks::Uniform(4));
    let per = run(CoreRanks::PerMode(vec![4, 4, 4]));
    assert_eq!(uni.fit(), per.fit(), "PerMode([K;N]) ≡ Uniform(K)");
    for (a, b) in uni.factors.iter().zip(&per.factors) {
        assert_eq!(a.data, b.data);
    }
    assert_eq!(uni.core.data, per.core.data);
}

#[test]
fn fit_grows_as_one_mode_rank_grows() {
    // planted multilinear rank (2,2,2): K = (2,2,1) cannot capture both
    // components, (2,2,2) captures everything (fit ≈ 1)
    let w = planted_rank2();
    let run = |core: Vec<usize>| {
        TuckerSession::builder(w.clone())
            .ranks(2)
            .core(CoreRanks::PerMode(core))
            .invocations(2)
            .seed(3)
            .build()
            .unwrap()
            .decompose()
            .fit()
    };
    let low = run(vec![2, 2, 1]);
    let high = run(vec![2, 2, 2]);
    assert!(high > 0.99, "full rank captures everything: {high}");
    assert!(
        high >= low - 1e-6,
        "fit must not shrink as K_2 grows: {low} -> {high}"
    );
    assert!(low < 0.99, "rank-deficient core cannot be exact: {low}");
}

#[test]
fn reconstruct_at_recovers_planted_tensor() {
    let w = planted_rank2();
    let mut s = TuckerSession::builder(w.clone())
        .ranks(2)
        .core(CoreRanks::Uniform(2))
        .invocations(2)
        .seed(5)
        .build()
        .unwrap();
    let d = s.decompose();
    assert!(d.fit() > 0.995, "exact multilinear rank: {}", d.fit());
    let t = &w.tensor;
    let scale = t.vals.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    for e in (0..t.nnz()).step_by(97) {
        let idx: Vec<usize> = (0..t.ndim()).map(|m| t.coord(m, e) as usize).collect();
        let got = d.reconstruct_at(&idx).expect("in-range index");
        assert!(
            (got - t.vals[e]).abs() < 5e-2 * scale.max(1.0),
            "entry {idx:?}: {got} vs {}",
            t.vals[e]
        );
    }
}

#[test]
fn ragged_core_plan_kp_tile_shapes() {
    // plan kp-tiling under a ragged core: kp tracks the *fast* other
    // mode's rank, K̂ the product of the others
    let mut rng = Rng::new(7);
    let t = SparseTensor::random(vec![20, 15, 10], 500, &mut rng);
    let core = CoreRanks::PerMode(vec![3, 9, 5]);
    let elems: Vec<u32> = (0..500).collect();
    let want = [
        // (mode, oks, khat)
        (0usize, vec![9usize, 5], 45usize),
        (1, vec![3, 5], 15),
        (2, vec![3, 9], 27),
    ];
    let factors: Vec<Mat> = t
        .dims
        .iter()
        .zip([3usize, 9, 5])
        .map(|(&l, k)| orthonormal_random(l as usize, k, &mut rng))
        .collect();
    let mut ws = PlanWorkspace::new();
    let mut ws_scalar = PlanWorkspace::with_kernel(Kernel::Scalar);
    for (mode, oks, kh) in want {
        let plan = TtmPlan::build_with(&t, mode, &elems, &core);
        assert_eq!(plan.oks, oks, "mode {mode} other-mode ranks");
        assert_eq!(plan.khat, kh, "mode {mode} khat");
        assert_eq!(plan.kp, pad_to_lanes(oks[0]), "mode {mode} kp tile");
        assert!(!plan.uniform_core());
        // ragged assembly matches the generalized element-order oracle,
        // on both the tiled and the scalar kernel
        let oracle = assemble_local_z_fused(&t, mode, &elems, &factors);
        let tiled = plan.assemble_fused(&factors, &mut ws);
        assert_eq!(tiled.rows, oracle.rows);
        assert_eq!(tiled.z.cols, kh);
        assert!(tiled.z.max_abs_diff(&oracle.z) < 1e-4, "tiled mode {mode}");
        ws.recycle(tiled.z);
        let scalar = plan.assemble_fused(&factors, &mut ws_scalar);
        assert!(scalar.z.max_abs_diff(&oracle.z) < 1e-4, "scalar mode {mode}");
        ws_scalar.recycle(scalar.z);
        // engine dispatch: ragged plans route around the batched
        // contract instead of violating it
        let via_engine = plan.assemble(&factors, &Engine::NativeBatched, &mut ws);
        assert!(via_engine.z.max_abs_diff(&oracle.z) < 1e-4);
        ws.recycle(via_engine.z);
    }
}

#[test]
fn typed_executor_and_kernel_choices_apply() {
    let w = tiny_workload();
    let mut s = TuckerSession::builder(w)
        .ranks(3)
        .core(4usize)
        .engine(EngineChoice::Native)
        .executor(ExecutorChoice::Serial)
        .kernel(KernelChoice::Fixed(Kernel::Scalar))
        .seed(1)
        .build()
        .unwrap();
    let d = s.decompose();
    assert_eq!(d.record.executor, "serial");
    assert_eq!(d.record.workers, 1);
    assert_eq!(d.record.kernel, "scalar");
}
