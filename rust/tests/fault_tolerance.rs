//! Fault-tolerance end-to-end: the headline acceptance property — a
//! single-rank crash injected at **every** `(sweep, phase)` position
//! recovers via survivor re-placement and lands a decomposition
//! **bit-identical** to a planned `evict_rank` at the rollback
//! boundary — plus transient-fault ≡ fault-free bit-identity, the
//! checkpoint serialize → parse → restore → resume round trip (3-D
//! property-tested, 4-D pinned), and the `RunRecord` recovery counters
//! with the Fig 11 phase-time sum invariance under both rank executors.

use tucker_lite::coordinator::{
    CheckpointPolicy, Decomposition, ExecutorChoice, RetryPolicy,
    SessionCheckpoint, TuckerSession, TuckerSessionBuilder, Workload,
};
use tucker_lite::dist::FaultPlan;
use tucker_lite::hooi::CoreRanks;
use tucker_lite::prop_assert;
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::check::Runner;
use tucker_lite::util::rng::Rng;

fn workload(dims: Vec<u32>, nnz: usize, seed: u64) -> Workload {
    let mut rng = Rng::new(seed);
    Workload::from_tensor("ft", SparseTensor::random(dims, nnz, &mut rng))
}

fn builder(w: &Workload, p: usize, k: usize, sweeps: usize) -> TuckerSessionBuilder {
    TuckerSession::builder(w.clone())
        .ranks(p)
        .core(CoreRanks::Uniform(k))
        .invocations(sweeps)
        .seed(17)
}

/// Upper bound on compute phases per sweep — a runaway guard for the
/// position enumeration, not a model of the real count (which the
/// enumeration discovers by probing until a position never fires).
const PHASE_CAP: usize = 64;

/// Acceptance: crash rank 2 at every `(sweep, phase)` position of a
/// 2-sweep run (including the post-sweep core phase, addressed as
/// `(sweeps, 0)`). Under `CheckpointPolicy::EverySweeps(1)` recovery
/// rolls back to boundary `b = min(sweep, sweeps - 1)`, so each run
/// must be bit-identical to a planned eviction at that boundary.
#[test]
fn crash_at_every_position_matches_planned_eviction() {
    const SWEEPS: usize = 2;
    const VICTIM: usize = 2;
    let w = workload(vec![14, 10, 8], 250, 5);

    // planned-eviction baselines, one per rollback boundary
    let baseline = |b: usize| -> Decomposition {
        if b == 0 {
            let mut s = builder(&w, 4, 2, SWEEPS).build().unwrap();
            s.evict_rank(VICTIM).expect("3 survivors");
            s.decompose()
        } else {
            let mut s = builder(&w, 4, 2, b).build().unwrap();
            s.decompose();
            s.evict_rank(VICTIM).expect("3 survivors");
            s.decompose_more(SWEEPS - b)
        }
    };
    let baselines: Vec<Decomposition> = (0..SWEEPS).map(baseline).collect();

    let mut positions = 0usize;
    for sweep in 0..=SWEEPS {
        let want = &baselines[sweep.min(SWEEPS - 1)];
        let mut phase = 0usize;
        loop {
            assert!(phase < PHASE_CAP, "phase enumeration runaway at sweep {sweep}");
            let mut s = builder(&w, 4, 2, SWEEPS)
                .fault_plan(FaultPlan::new().crash_at(sweep, phase, VICTIM))
                .build()
                .unwrap();
            let got = s
                .try_decompose()
                .unwrap_or_else(|e| panic!("sweep {sweep} phase {phase}: {e}"));
            if s.faults_injected() == 0 {
                // position (sweep, phase) does not exist: the sweep has
                // exactly `phase` compute phases — enumeration complete
                break;
            }
            positions += 1;
            assert_eq!(s.dead_ranks(), vec![VICTIM], "sweep {sweep} phase {phase}");
            assert!(s.recoveries() >= 1, "sweep {sweep} phase {phase}");
            assert_eq!(got.record.faults_injected, 1);
            assert!(got.record.recoveries >= 1);
            assert!(got.record.recovery_secs > 0.0);
            // the dead rank owns nothing after survivor re-placement
            for pol in &s.placement().dist.policies {
                assert!(pol.assign.iter().all(|&r| r != VICTIM as u32));
            }
            for (n, (a, b)) in want.factors.iter().zip(&got.factors).enumerate() {
                assert_eq!(
                    a.data, b.data,
                    "sweep {sweep} phase {phase}: mode {n} factor bits"
                );
            }
            assert_eq!(
                want.core.data, got.core.data,
                "sweep {sweep} phase {phase}: core bits"
            );
            assert_eq!(want.record.fit.to_bits(), got.record.fit.to_bits());
            phase += 1;
        }
        if sweep < SWEEPS {
            assert!(phase > 0, "sweep {sweep} ran no compute phases");
        } else {
            assert_eq!(phase, 1, "the post-sweep position holds only the core phase");
        }
    }
    // every sweep contributed at least TTM + SVD phases per mode, plus
    // the core phase — the enumeration really swept the space
    assert!(positions > SWEEPS * 2 * 3, "only {positions} positions probed");
}

/// A transient failure (retry succeeds) at one position per sweep must
/// roll back and land exactly the fault-free bits — no placement
/// change, no dead ranks.
#[test]
fn transient_faults_are_bit_invisible_after_recovery() {
    let w = workload(vec![15, 12, 9], 300, 6);
    let clean = builder(&w, 4, 3, 2).build().unwrap().decompose();
    for (sweep, phase) in [(0, 0), (0, 3), (1, 1), (2, 0)] {
        let mut s = builder(&w, 4, 3, 2)
            .fault_plan(FaultPlan::new().transient_at(sweep, phase, 1))
            .build()
            .unwrap();
        let d = s.try_decompose().expect("transient recovers");
        assert_eq!(s.faults_injected(), 1, "({sweep},{phase})");
        assert_eq!(s.recoveries(), 1, "({sweep},{phase})");
        assert!(s.dead_ranks().is_empty());
        for (a, b) in clean.factors.iter().zip(&d.factors) {
            assert_eq!(a.data, b.data, "({sweep},{phase}) factor bits");
        }
        assert_eq!(clean.core.data, d.core.data, "({sweep},{phase}) core bits");
        assert_eq!(clean.record.fit.to_bits(), d.record.fit.to_bits());
    }
}

/// Checkpoint round trip, property-tested over random 3-D tensors:
/// serialize → parse is field-exact, and restoring the parsed
/// checkpoint into a freshly built (identical) session resumes
/// bit-identically to the original session.
#[test]
fn checkpoint_roundtrip_resumes_bit_exactly_3d() {
    Runner::new(10, 60).run("checkpoint-roundtrip-3d", |case, rng| {
        let dims = vec![
            8 + rng.usize_below(case.size + 8) as u32,
            6 + rng.usize_below(case.size + 6) as u32,
            5 + rng.usize_below(case.size + 5) as u32,
        ];
        let nnz = 120 + rng.usize_below(4 * case.size + 40);
        let p = 2 + rng.usize_below(3);
        let k = 2 + rng.usize_below(2);
        let w = workload(dims.clone(), nnz, rng.next_u64());

        let mut original = builder(&w, p, k, 2).build().unwrap();
        original.decompose();
        let cp = original.checkpoint().expect("state to checkpoint");
        let wire = SessionCheckpoint::parse(&cp.serialize())
            .map_err(|e| format!("parse failed: {e}"))?;
        prop_assert!(wire.sweep == cp.sweep, "sweep {} != {}", wire.sweep, cp.sweep);
        prop_assert!(wire.p == cp.p, "p mismatch");
        prop_assert!(wire.ks == cp.ks, "ks mismatch");
        prop_assert!(wire.rng_state == cp.rng_state, "rng state mismatch");
        prop_assert!(wire.sigma == cp.sigma, "sigma mismatch");
        for (n, (a, b)) in cp.factors.iter().zip(&wire.factors).enumerate() {
            prop_assert!(a.data == b.data, "serialized factor {n} not bit-exact");
        }

        let mut resumed = builder(&w, p, k, 2).build().unwrap();
        resumed.restore(&wire).map_err(|e| format!("restore failed: {e}"))?;
        let a = original.decompose_more(1);
        let b = resumed.decompose_more(1);
        for (n, (fa, fb)) in a.factors.iter().zip(&b.factors).enumerate() {
            prop_assert!(
                fa.data == fb.data,
                "dims {dims:?} p {p} k {k}: mode {n} factor bits diverge"
            );
        }
        prop_assert!(a.core.data == b.core.data, "core bits diverge");
        prop_assert!(a.record.fit == b.record.fit, "fit diverges");
        Ok(())
    });
}

/// The 4-D pin of the round trip: one fixed seed, one extra mode.
#[test]
fn checkpoint_roundtrip_resumes_bit_exactly_4d_pin() {
    let w = workload(vec![8, 7, 6, 5], 300, 9);
    let mut original = builder(&w, 3, 2, 2).build().unwrap();
    original.decompose();
    let cp = original.checkpoint().expect("state to checkpoint");
    assert_eq!(cp.sweep, 2);
    assert_eq!(cp.ks, vec![2, 2, 2, 2]);
    let wire = SessionCheckpoint::parse(&cp.serialize()).expect("parses");
    let mut resumed = builder(&w, 3, 2, 2).build().unwrap();
    resumed.restore(&wire).expect("restores");
    let a = original.decompose_more(1);
    let b = resumed.decompose_more(1);
    for (n, (fa, fb)) in a.factors.iter().zip(&b.factors).enumerate() {
        assert_eq!(fa.data, fb.data, "mode {n} factor bits");
    }
    assert_eq!(a.core.data, b.core.data, "core bits");
    assert_eq!(a.record.fit.to_bits(), b.record.fit.to_bits());
}

/// Recovery observability under both rank executors: the counters
/// surface in `RunRecord`, checkpoints cost bytes, the recovery bucket
/// stays out of `hooi_secs` (Fig 11 phase-time sum invariance), and the
/// recovered bits do not depend on the executor.
#[test]
fn recovery_counters_and_sum_invariance_under_both_executors() {
    let w = workload(vec![14, 10, 8], 250, 5);
    let run = |executor: ExecutorChoice| -> Decomposition {
        let mut s = builder(&w, 4, 2, 2)
            .executor(executor)
            .fault_plan(FaultPlan::new().crash_at(1, 1, 3))
            .checkpoint_policy(CheckpointPolicy::EverySweeps(1))
            .retry_policy(RetryPolicy { max_attempts: 3, straggler_timeout: None })
            .build()
            .unwrap();
        let d = s.try_decompose().expect("recovers");
        assert_eq!(s.dead_ranks(), vec![3]);
        d
    };
    let serial = run(ExecutorChoice::Serial);
    let parallel = run(ExecutorChoice::Parallel);
    for d in [&serial, &parallel] {
        assert_eq!(d.record.faults_injected, 1);
        assert_eq!(d.record.recoveries, 1);
        assert!(d.record.recovery_secs > 0.0);
        assert!(d.record.checkpoint_bytes > 0);
        assert!(d.record.checkpoint_secs >= 0.0);
        // Fig 11 breakdown: recovery and checkpoint time live in the
        // cat::OUT_OF_PHASE_SUM buckets; the cat::IN_PHASE_SUM phases
        // (compute + comm) still sum to hooi_secs
        let sum = d.record.ttm_secs
            + d.record.svd_secs
            + d.record.core_secs
            + d.record.comm_secs;
        assert!(
            (sum - d.record.hooi_secs).abs() < 1e-9,
            "phase sum {sum} != hooi {}",
            d.record.hooi_secs
        );
    }
    for (n, (a, b)) in serial.factors.iter().zip(&parallel.factors).enumerate() {
        assert_eq!(a.data, b.data, "mode {n} factor bits diverge across executors");
    }
    assert_eq!(serial.core.data, parallel.core.data);
    assert_eq!(serial.record.fit.to_bits(), parallel.record.fit.to_bits());
}
