//! Property suite for the paper's formal claims, run on adversarial
//! randomized tensors through the in-tree mini property-testing framework
//! (util::check). Complements the unit-level properties in sched::lite.

use tucker_lite::prop_assert;
use tucker_lite::sched::{self, ModeMetrics, Scheme, Sharers};
use tucker_lite::tensor::slices::build_all;
use tucker_lite::tensor::synth::{generate, ModeDist};
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::check::Runner;
use tucker_lite::util::rng::Rng;

/// Random tensor with occasional pathological skew (giant slices), the
/// regime Theorem 6.1 is designed for.
fn adversarial_tensor(size: usize, rng: &mut Rng) -> SparseTensor {
    let ndim = if rng.below(2) == 0 { 3 } else { 4 };
    let modes: Vec<ModeDist> = (0..ndim)
        .map(|_| ModeDist {
            len: 1 + rng.below(size as u64 * 2 + 2) as u32,
            zipf: match rng.below(3) {
                0 => 0.0,
                1 => 0.9,
                _ => 1.6, // heavy head: giant slices
            },
        })
        .collect();
    let nnz = 1 + rng.usize_below(size * 20 + 20);
    generate(&modes, nnz, rng.next_u64())
}

#[test]
fn theorem_6_1_holds_on_adversarial_tensors() {
    Runner::new(40, 80).run("thm6.1-adversarial", |case, rng| {
        let t = adversarial_tensor(case.size.max(2), rng);
        let p = 1 + rng.usize_below(12);
        let idx = build_all(&t);
        let d = sched::Lite.policies(&t, &idx, p, rng);
        let limit = t.nnz().div_ceil(p);
        for (n, i) in idx.iter().enumerate() {
            let m = ModeMetrics::compute(i, &d.policies[n]);
            prop_assert!(m.e_max <= limit, "E_max {} > {limit} (mode {n})", m.e_max);
            prop_assert!(
                m.r_sum <= i.num_slices() + p,
                "R_sum {} > L+P (mode {n})",
                m.r_sum
            );
            prop_assert!(
                m.r_max <= i.num_slices().div_ceil(p) + 2,
                "R_max {} > ceil(L/P)+2 (mode {n})",
                m.r_max
            );
        }
        Ok(())
    });
}

#[test]
fn every_scheme_partitions_every_element_exactly_once() {
    Runner::new(24, 60).run("partition-completeness", |case, rng| {
        let t = adversarial_tensor(case.size.max(2), rng);
        let p = 1 + rng.usize_below(8);
        let idx = build_all(&t);
        for scheme in sched::all_schemes() {
            let d = scheme.policies(&t, &idx, p, rng);
            d.validate(&t)?;
            for (n, pol) in d.policies.iter().enumerate() {
                let total: usize = pol.rank_counts().iter().sum();
                prop_assert!(
                    total == t.nnz(),
                    "{}: mode {n} assigns {total} != nnz {}",
                    scheme.name(),
                    t.nnz()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn coarse_grained_slices_always_good() {
    Runner::new(24, 60).run("coarseg-good-slices", |case, rng| {
        let t = adversarial_tensor(case.size.max(2), rng);
        let p = 1 + rng.usize_below(8);
        let idx = build_all(&t);
        let d = sched::CoarseG::default().policies(&t, &idx, p, rng);
        for (n, i) in idx.iter().enumerate() {
            let sharers = Sharers::build(i, &d.policies[n]);
            prop_assert!(
                sharers.bad_slices() == 0,
                "mode {n}: {} bad slices",
                sharers.bad_slices()
            );
        }
        Ok(())
    });
}

#[test]
fn row_owner_is_always_a_sharer() {
    Runner::new(24, 60).run("sigma-owner-shares", |case, rng| {
        let t = adversarial_tensor(case.size.max(2), rng);
        let p = 1 + rng.usize_below(8);
        let idx = build_all(&t);
        for scheme in sched::all_schemes() {
            let d = scheme.policies(&t, &idx, p, rng);
            for (n, i) in idx.iter().enumerate() {
                let sharers = Sharers::build(i, &d.policies[n]);
                let map = sched::RowMap::build(&sharers, p);
                for l in 0..i.num_slices() {
                    let s = sharers.of(l);
                    if !s.is_empty() {
                        prop_assert!(
                            s.contains(&map.of(l)),
                            "{}: mode {n} slice {l} owner not a sharer",
                            scheme.name()
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn hooi_fit_bounded_and_deterministic() {
    use tucker_lite::coordinator::{run_scheme, Workload};
    use tucker_lite::dist::NetModel;
    use tucker_lite::runtime::Engine;
    use tucker_lite::tensor::slices::build_all as _;

    Runner::new(8, 30).run("hooi-fit", |case, rng| {
        let t = adversarial_tensor(case.size.max(4), rng);
        if t.nnz() < 8 {
            return Ok(());
        }
        let idx = build_all(&t);
        let w = Workload { name: "prop".into(), tensor: t, idx };
        let p = 1 + rng.usize_below(4);
        let k = 1 + rng.usize_below(4);
        let rec = run_scheme(
            &w,
            &sched::Lite,
            p,
            k,
            1,
            &Engine::Native,
            NetModel::default(),
            case.seed,
        );
        prop_assert!(rec.fit.is_finite(), "fit NaN");
        prop_assert!(
            (-1e-6..=1.0 + 1e-6).contains(&rec.fit),
            "fit out of range: {}",
            rec.fit
        );
        Ok(())
    });
}
