//! Rebalance end-to-end: `MigrationPlan` sanity (moved sets exact and
//! disjoint, volumes matching the byte accounting, empty diff ⇒ no
//! plan rebuilds), the `plan_rebuilds()` contract (a rebalance touches
//! exactly the diffed (mode, rank) plans), the `RebalancePolicy::Auto`
//! cost-model decision surfacing in `RunRecord`, and the headline
//! equivalence: `ingest` + `rebalance()` + `decompose_more` is
//! **bit-identical** to a fresh session on the mutated tensor under the
//! re-planned placement (3-D property-tested, 4-D pinned).

use tucker_lite::coordinator::{
    RebalancePolicy, SchemeChoice, TuckerSession, Workload,
};
use tucker_lite::hooi::CoreRanks;
use tucker_lite::prop_assert;
use tucker_lite::sched::{DistTime, Distribution, MigrationPlan, ModePolicy, Scheme};
use tucker_lite::tensor::{SliceIndex, SparseTensor, TensorDelta};
use tucker_lite::util::check::Runner;
use tucker_lite::util::rng::Rng;

/// A scheme that replays a fixed distribution — pins "the same
/// placement" when comparing a rebalanced session against a fresh
/// build.
struct Fixed(Distribution);

impl Scheme for Fixed {
    fn name(&self) -> &'static str {
        "Fixed"
    }

    fn uni(&self) -> bool {
        self.0.uni
    }

    fn policies(
        &self,
        _t: &SparseTensor,
        _idx: &[SliceIndex],
        _p: usize,
        _rng: &mut Rng,
    ) -> Distribution {
        self.0.clone()
    }
}

/// A deliberately scattered placement: element e → rank e mod P along
/// every mode. Every populated slice is shared by (almost) every rank,
/// so the Theorem 6.1 sharing bounds are violated from the start and
/// any structural ingest flags every mode.
fn scattered(t: &SparseTensor, p: usize) -> Distribution {
    let assign: Vec<u32> = (0..t.nnz()).map(|e| (e % p) as u32).collect();
    Distribution {
        scheme: "Scatter".into(),
        p,
        policies: (0..t.ndim()).map(|_| ModePolicy::new(p, assign.clone())).collect(),
        uni: false,
        time: DistTime::default(),
    }
}

fn scattered_session(
    t: SparseTensor,
    p: usize,
    k: usize,
    policy: RebalancePolicy,
) -> TuckerSession {
    let dist = scattered(&t, p);
    TuckerSession::builder(Workload::from_tensor("scatter", t))
        .scheme(SchemeChoice::custom(Box::new(Fixed(dist))))
        .ranks(p)
        .core(CoreRanks::Uniform(k))
        .rebalance_policy(policy)
        .seed(7)
        .build()
        .expect("valid scattered session")
}

fn random_delta(t: &SparseTensor, rng: &mut Rng, n_app: usize) -> TensorDelta {
    let mut d = TensorDelta::new();
    for _ in 0..n_app {
        let coord: Vec<u32> =
            t.dims.iter().map(|&l| rng.below(l as u64) as u32).collect();
        d = d.append(&coord, rng.f32() * 2.0 - 1.0);
    }
    d
}

#[test]
fn rebalance_rebuilds_only_the_diffed_plans() {
    let mut rng = Rng::new(3);
    let t = SparseTensor::random(vec![24, 16, 12], 1200, &mut rng);
    let p = 4;
    let mut s = scattered_session(t, p, 3, RebalancePolicy::Manual);
    // the scattered placement breaks the R bounds; the first structural
    // ingest revalidates and flags every mode
    let rep = s.ingest(&TensorDelta::new().append(&[0, 0, 0], 0.5)).unwrap();
    assert!(!rep.rebalance_modes.is_empty(), "scattered placement must flag");
    assert!(rep.rebalance.is_none(), "Manual leaves the decision to the caller");
    assert_eq!(s.pending_rebalance(), &rep.rebalance_modes[..]);

    let before = s.distribution().clone();
    let rebuilds_before = s.plan_rebuilds();
    let rb = s.rebalance();
    assert!(rb.migrated);
    assert_eq!(rb.modes, rep.rebalance_modes);
    // the migration plan recomputed from the before/after snapshots
    // must agree with what the session applied: exactly the diffed
    // (mode, rank) plans were touched, never a full re-prepare
    let mig = MigrationPlan::compute(&before, s.distribution());
    assert!(!mig.is_empty());
    assert_eq!(rb.moved_elements, mig.moved_elements);
    assert_eq!(rb.migration_bytes, mig.bytes);
    if s.shared_plans().is_some() {
        // under TUCKER_PLAN=shared the unit of maintenance is the
        // rank's one tree: a rank dirtied by any mode's move rebuilds
        // exactly once
        let dirty_ranks = (0..p)
            .filter(|&r| {
                mig.per_mode.iter().any(|mm| {
                    !mm.incoming[r].is_empty() || !mm.outgoing[r].is_empty()
                })
            })
            .count();
        assert_eq!(
            s.plan_rebuilds() - rebuilds_before,
            dirty_ranks,
            "rebalance rebuilds exactly the dirty ranks' trees"
        );
        assert_eq!(rb.plans_spliced + rb.plans_rebuilt, dirty_ranks);
    } else {
        assert_eq!(
            s.plan_rebuilds() - rebuilds_before,
            mig.dirty_plans(),
            "rebalance touches exactly the diffed (mode, rank) plans"
        );
        assert_eq!(rb.plans_spliced + rb.plans_rebuilt, mig.dirty_plans());
    }
    assert_eq!(s.plan_builds(), 1, "never a full re-prepare");
    assert!(s.pending_rebalance().is_empty(), "fresh Lite satisfies the bounds");
    assert!(s.decompose().fit().is_finite());
}

#[test]
fn auto_policy_migrates_when_the_cost_model_amortizes() {
    let mut rng = Rng::new(5);
    let t = SparseTensor::random(vec![24, 16, 12], 1200, &mut rng);
    let mut s = scattered_session(
        t,
        4,
        3,
        RebalancePolicy::Auto { hooi_iters_amortization: 1_000_000 },
    );
    let rep = s.ingest(&TensorDelta::new().append(&[1, 1, 1], 0.5)).unwrap();
    let rb = rep.rebalance.expect("auto policy decides on every flagged ingest");
    // scattered → Lite slashes the R metrics: the model must see
    // savings, and a huge horizon amortizes any migration
    assert!(
        rb.decision.savings_per_sweep > 0.0,
        "Lite re-plan must be cheaper than scatter: {:?}",
        rb.decision
    );
    assert!(rb.decision.migrate && rb.migrated);
    assert!(rb.moved_elements > 0);
    assert!(rb.migration_bytes > 0);
    assert!(s.pending_rebalance().is_empty());
    // the outcome is visible in the run record (Fig 16 side)
    let d = s.decompose();
    assert_eq!(d.record.rebalances, 1);
    assert_eq!(d.record.rebalance_skips, 0);
    assert!(d.record.redist_secs > 0.0);
    assert!(d.record.dist_secs > 0.0);
}

#[test]
fn auto_policy_zero_horizon_skips_and_keeps_the_flags() {
    let mut rng = Rng::new(7);
    let t = SparseTensor::random(vec![24, 16, 12], 1200, &mut rng);
    let mut s = scattered_session(
        t,
        4,
        3,
        RebalancePolicy::Auto { hooi_iters_amortization: 0 },
    );
    let rebuilds_after_build = s.plan_rebuilds();
    let rep = s.ingest(&TensorDelta::new().append(&[2, 2, 2], 0.5)).unwrap();
    let rb = rep.rebalance.expect("auto policy still evaluates");
    assert!(
        !rb.migrated,
        "zero amortization sweeps can never pay for a migration"
    );
    assert_eq!(rb.plans_spliced + rb.plans_rebuilt, 0);
    // only the ingest's own dirty plans were touched, not a migration
    assert_eq!(s.plan_rebuilds() - rebuilds_after_build, rep.plans_touched());
    assert!(!s.pending_rebalance().is_empty(), "flags stay until a migration lands");
    let d = s.decompose();
    assert_eq!(d.record.rebalances, 0);
    assert!(d.record.rebalance_skips >= 1);
}

#[test]
fn migration_plan_sanity_properties() {
    Runner::new(12, 40).run("migration-plan-sanity", |case, rng| {
        let p = 2 + rng.usize_below(5);
        let ndim = if case.index % 2 == 0 { 3 } else { 4 };
        let dims: Vec<u32> = (0..ndim)
            .map(|m| (4 + rng.usize_below(case.size + 10 - m)) as u32)
            .collect();
        let nnz = 50 + rng.usize_below(case.size * 8 + 50);
        let t = SparseTensor::random(dims, nnz, rng);
        let mk = |rng: &mut Rng| -> Distribution {
            Distribution {
                scheme: "rand".into(),
                p,
                policies: (0..t.ndim())
                    .map(|_| {
                        ModePolicy::new(
                            p,
                            (0..t.nnz())
                                .map(|_| rng.below(p as u64) as u32)
                                .collect(),
                        )
                    })
                    .collect(),
                uni: false,
                time: DistTime::default(),
            }
        };
        let a = mk(rng);
        let b = mk(rng);
        let m = MigrationPlan::compute(&a, &b);
        prop_assert!(m.bytes_per_element == (t.ndim() as u64 + 1) * 4, "bpe");
        prop_assert!(
            m.bytes == m.moved_elements as u64 * m.bytes_per_element,
            "volumes match the byte accounting"
        );
        for (n, mm) in m.per_mode.iter().enumerate() {
            let moved_direct = a.policies[n]
                .assign
                .iter()
                .zip(b.policies[n].assign.iter())
                .filter(|(x, y)| x != y)
                .count();
            prop_assert!(mm.moved() == moved_direct, "mode {n} moved count");
            let out_total: usize = mm.outgoing.iter().map(Vec::len).sum();
            prop_assert!(out_total == moved_direct, "outgoing mirrors incoming");
            for r in 0..p {
                for &e in &mm.incoming[r] {
                    prop_assert!(
                        b.policies[n].assign[e as usize] as usize == r,
                        "incoming element owned by its destination"
                    );
                    prop_assert!(
                        a.policies[n].assign[e as usize] as usize != r,
                        "incoming element really moved"
                    );
                    prop_assert!(
                        !mm.outgoing[r].contains(&e),
                        "moved sets disjoint per rank"
                    );
                }
            }
            // each element appears in exactly one rank's incoming list
            let mut all_in: Vec<u32> =
                mm.incoming.iter().flatten().copied().collect();
            all_in.sort_unstable();
            let len = all_in.len();
            all_in.dedup();
            prop_assert!(all_in.len() == len, "incoming sets disjoint across ranks");
        }
        // self-diff is empty
        let empty = MigrationPlan::compute(&a, &a);
        prop_assert!(empty.is_empty() && empty.dirty_plans() == 0, "self-diff");
        Ok(())
    });
}

#[test]
fn ingest_rebalance_decompose_matches_fresh_session_3d() {
    Runner::new(8, 25).run("rebalance-fresh-equivalence", |case, rng| {
        let p = 2 + rng.usize_below(4);
        let k = 2 + rng.usize_below(3);
        let dims = vec![
            (8 + rng.usize_below(case.size + 8)) as u32,
            (6 + rng.usize_below(12)) as u32,
            (4 + rng.usize_below(8)) as u32,
        ];
        let nnz = 150 + rng.usize_below(case.size * 10 + 50);
        let t = SparseTensor::random(dims, nnz, rng);
        let w = Workload::from_tensor("stream", t);
        let mut streamed = TuckerSession::builder(w)
            .scheme(SchemeChoice::Lite)
            .ranks(p)
            .core(CoreRanks::Uniform(k))
            .invocations(1)
            .seed(31)
            .build()
            .expect("valid streamed session");
        let delta =
            random_delta(&streamed.workload().tensor, rng, 5 + rng.usize_below(40));
        streamed.ingest(&delta).map_err(|e| e.to_string())?;
        let rb = streamed.rebalance();
        // migrated or not (empty diffs are legal), the live placement
        // must now behave exactly like a fresh build under it
        let w2 =
            Workload::from_tensor("fresh", streamed.workload().tensor.clone());
        let mut fresh = TuckerSession::builder(w2)
            .scheme(SchemeChoice::custom(Box::new(Fixed(
                streamed.distribution().clone(),
            ))))
            .ranks(p)
            .core(CoreRanks::Uniform(k))
            .invocations(2)
            .seed(31)
            .build()
            .expect("valid fresh session");
        let d_inc = streamed.decompose_more(1); // virgin: 1 configured + 1
        let d_fresh = fresh.decompose();
        prop_assert!(
            d_inc.fit() == d_fresh.fit(),
            "fit {} vs fresh {} (migrated: {})",
            d_inc.fit(),
            d_fresh.fit(),
            rb.migrated
        );
        for (n, (x, y)) in d_inc.factors.iter().zip(&d_fresh.factors).enumerate() {
            prop_assert!(x.data == y.data, "mode {n} factors diverge");
        }
        prop_assert!(d_inc.core.data == d_fresh.core.data, "cores diverge");
        Ok(())
    });
}

#[test]
fn ingest_rebalance_decompose_matches_fresh_session_4d() {
    let mut rng = Rng::new(19);
    let t = SparseTensor::random(vec![10, 8, 6, 5], 500, &mut rng);
    let w = Workload::from_tensor("stream4d", t);
    let mut streamed = TuckerSession::builder(w)
        .scheme(SchemeChoice::Lite)
        .ranks(3)
        .core(CoreRanks::Uniform(3))
        .invocations(1)
        .seed(23)
        .build()
        .unwrap();
    let delta = random_delta(&streamed.workload().tensor, &mut rng, 30);
    streamed.ingest(&delta).unwrap();
    let rb = streamed.rebalance();
    assert!(rb.migrated, "a fresh Lite re-plan of a grown tensor moves elements");
    let w2 = Workload::from_tensor("fresh4d", streamed.workload().tensor.clone());
    let mut fresh = TuckerSession::builder(w2)
        .scheme(SchemeChoice::custom(Box::new(Fixed(
            streamed.distribution().clone(),
        ))))
        .ranks(3)
        .core(CoreRanks::Uniform(3))
        .invocations(1)
        .seed(23)
        .build()
        .unwrap();
    let d_inc = streamed.decompose();
    let d_fresh = fresh.decompose();
    assert_eq!(d_inc.fit(), d_fresh.fit());
    for (x, y) in d_inc.factors.iter().zip(&d_fresh.factors) {
        assert_eq!(x.data, y.data);
    }
    assert_eq!(d_inc.core.data, d_fresh.core.data);
    assert_eq!(streamed.plan_builds(), 1, "migration never re-runs prepare_modes");
}
