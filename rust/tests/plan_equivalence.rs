//! Plan-layer equivalence properties: precompiled TTM plans must
//! reproduce the element-order oracle (`assemble_local_z_fused`) for
//! random tensors, random partitions and degenerate ranks — and the
//! parallel rank executor must be bit-identical to serial execution.

use tucker_lite::dist::{cat, SimCluster};
use tucker_lite::hooi::{
    assemble_local_z_fused, run_hooi, CoreRanks, HooiConfig, LocalZ, PlanWorkspace, TtmPlan,
};
use tucker_lite::linalg::{orthonormal_random, Mat};
use tucker_lite::runtime::Engine;
use tucker_lite::sched::{Lite, Scheme};
use tucker_lite::tensor::slices::build_all;
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::rng::Rng;

fn random_factors(t: &SparseTensor, k: usize, rng: &mut Rng) -> Vec<Mat> {
    t.dims
        .iter()
        .map(|&l| orthonormal_random(l as usize, k, rng))
        .collect()
}

fn random_partition(nnz: usize, p: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    let mut out = vec![Vec::new(); p];
    for e in 0..nnz as u32 {
        out[rng.usize_below(p)].push(e);
    }
    out
}

/// One randomized case: every (mode, rank) plan assembly must match the
/// element-order oracle in rows exactly and values up to f32
/// reassociation.
fn check_case(dims: Vec<u32>, nnz: usize, k: usize, p: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let t = SparseTensor::random(dims, nnz, &mut rng);
    let factors = random_factors(&t, k, &mut rng);
    let per_rank = random_partition(t.nnz(), p, &mut rng);
    let mut ws = PlanWorkspace::new();
    for mode in 0..t.ndim() {
        for elems in &per_rank {
            let plan = TtmPlan::build(&t, mode, elems, k);
            let want = assemble_local_z_fused(&t, mode, elems, &factors);
            let fused = plan.assemble_fused(&factors, &mut ws);
            assert_eq!(fused.rows, want.rows, "mode {mode} rows");
            assert!(
                fused.z.max_abs_diff(&want.z) < 1e-4,
                "mode {mode} fused diff {}",
                fused.z.max_abs_diff(&want.z)
            );
            ws.recycle(fused.z);
            let batched = plan.assemble(&factors, &Engine::NativeBatched, &mut ws);
            assert_eq!(batched.rows, want.rows, "mode {mode} batched rows");
            assert!(
                batched.z.max_abs_diff(&want.z) < 1e-4,
                "mode {mode} batched diff {}",
                batched.z.max_abs_diff(&want.z)
            );
            ws.recycle(batched.z);
        }
    }
}

#[test]
fn plan_matches_oracle_on_random_3d_tensors() {
    // Miri interprets every load/store, so each sweep shrinks to one
    // small case there — the point under Miri is UB detection in the
    // plan pointer arithmetic, not statistical coverage (CI runs the
    // full sweep natively as well)
    let cases: &[(usize, usize, usize)] = if cfg!(miri) {
        &[(120, 2, 3)]
    } else {
        &[(900, 4, 5), (300, 7, 3), (1200, 2, 6)]
    };
    for (seed, &(nnz, p, k)) in cases.iter().enumerate() {
        check_case(vec![20, 14, 9], nnz, k, p, seed as u64 + 1);
    }
}

#[test]
fn plan_matches_oracle_on_random_4d_tensors() {
    let cases: &[(usize, usize, usize)] =
        if cfg!(miri) { &[(90, 2, 3)] } else { &[(700, 3, 3), (250, 5, 4)] };
    for (seed, &(nnz, p, k)) in cases.iter().enumerate() {
        check_case(vec![10, 8, 6, 5], nnz, k, p, seed as u64 + 10);
    }
}

#[test]
fn plan_matches_oracle_with_many_empty_ranks() {
    // P far exceeds nnz: most ranks get no elements at all
    check_case(vec![12, 12, 12], 6, 3, 16, 77);
}

#[test]
fn explicitly_empty_rank_matches_oracle() {
    let mut rng = Rng::new(5);
    let t = SparseTensor::random(vec![9, 9, 9], 200, &mut rng);
    let factors = random_factors(&t, 4, &mut rng);
    let plan = TtmPlan::build(&t, 1, &[], 4);
    let mut ws = PlanWorkspace::new();
    let local = plan.assemble(&factors, &Engine::Native, &mut ws);
    let want = assemble_local_z_fused(&t, 1, &[], &factors);
    assert_eq!(local.rows, want.rows);
    assert!(local.rows.is_empty());
    assert_eq!(local.z.rows, 0);
}

#[test]
fn concurrent_phase_is_bit_identical_to_serial() {
    let p = 6;
    let k = 5;
    let nnz = if cfg!(miri) { 400 } else { 4000 };
    let mut rng = Rng::new(42);
    let t = SparseTensor::random(vec![40, 25, 15], nnz, &mut rng);
    let factors = random_factors(&t, k, &mut rng);
    let per_rank = random_partition(t.nnz(), p, &mut rng);
    let plans: Vec<TtmPlan> =
        per_rank.iter().map(|es| TtmPlan::build(&t, 0, es, k)).collect();

    let assemble_all = |parallel: bool| -> Vec<LocalZ> {
        let mut cluster = SimCluster::new(p).with_parallel(parallel);
        let mut workspaces: Vec<PlanWorkspace> =
            (0..p).map(|_| PlanWorkspace::new()).collect();
        let factors_ref = &factors;
        let tasks: Vec<_> = plans
            .iter()
            .zip(workspaces.iter_mut())
            .map(|(plan, ws)| {
                move || plan.assemble(factors_ref, &Engine::Native, ws)
            })
            .collect();
        let out = cluster
            .phase_tasks(cat::TTM, tasks)
            .expect("no fault injector armed in this test");
        assert!(cluster.elapsed.get(cat::TTM) >= 0.0);
        assert_eq!(cluster.last_phase.len(), p);
        out
    };

    let serial = assemble_all(false);
    let concurrent = assemble_all(true);
    assert_eq!(serial.len(), concurrent.len());
    for (rank, (a, b)) in serial.iter().zip(&concurrent).enumerate() {
        assert_eq!(a.rows, b.rows, "rank {rank} rows");
        // bit-identical: same kernel, same rank-local arithmetic order
        assert_eq!(a.z.data, b.z.data, "rank {rank} Z bits");
    }
}

#[test]
fn hooi_end_to_end_identical_under_both_executors() {
    let mut rng = Rng::new(9);
    let nnz = if cfg!(miri) { 200 } else { 700 };
    let t = SparseTensor::random(vec![18, 14, 10], nnz, &mut rng);
    let idx = build_all(&t);
    let dist = Lite.policies(&t, &idx, 4, &mut Rng::new(3));
    let cfg = HooiConfig {
        core: CoreRanks::Uniform(4),
        invocations: if cfg!(miri) { 1 } else { 2 },
        seed: 11,
        ..HooiConfig::default()
    };
    let mut serial = SimCluster::serial(4);
    let out_s = run_hooi(&t, &idx, &dist, &Engine::Native, &mut serial, &cfg);
    let mut parallel = SimCluster::new(4).with_parallel(true);
    let out_p = run_hooi(&t, &idx, &dist, &Engine::Native, &mut parallel, &cfg);
    assert_eq!(out_s.fit.to_bits(), out_p.fit.to_bits(), "fit identical");
    for (n, (a, b)) in out_s.factors.iter().zip(&out_p.factors).enumerate() {
        assert_eq!(a.data, b.data, "mode {n} factor bits");
    }
    assert_eq!(out_s.core.data, out_p.core.data, "core bits");
}
