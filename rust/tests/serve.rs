//! Serving-layer integration: the batched query engine pinned
//! **bit-identical** to the per-element oracle under every kernel
//! (3-D property + 4-D pin), typed query errors, top-K against a
//! full-sort oracle, snapshot consistency under concurrent
//! ingest/rebalance/refine, bit-exact snapshot serialization, and the
//! multi-tenant coordinator's budget/LRU/telemetry contracts.

use std::sync::Arc;

use tucker_lite::coordinator::{SchemeChoice, TuckerSession, Workload};
use tucker_lite::hooi::{CoreRanks, Kernel};
use tucker_lite::linalg::Mat;
use tucker_lite::prop_assert;
use tucker_lite::serve::{
    AdmissionError, DecompositionSnapshot, QueryBatch, QueryError, ServeBudget,
    ServeCoordinator, ServeError,
};
use tucker_lite::tensor::{SparseTensor, TensorDelta};
use tucker_lite::util::check::Runner;
use tucker_lite::util::rng::Rng;

/// A synthetic Tucker model with the library's layout contract:
/// factors L_n × K_n, core flattened K_{N−1} × K̂ (earliest mode
/// fastest along the columns).
fn random_model(rng: &mut Rng, dims: &[usize], ks: &[usize]) -> DecompositionSnapshot {
    let factors: Vec<Mat> = dims
        .iter()
        .zip(ks)
        .map(|(&l, &k)| {
            let mut m = Mat::zeros(l, k);
            for v in m.data.iter_mut() {
                *v = rng.f32() * 2.0 - 1.0;
            }
            m
        })
        .collect();
    let n = ks.len();
    let kh: usize = ks[..n - 1].iter().product();
    let mut core = Mat::zeros(ks[n - 1], kh);
    for v in core.data.iter_mut() {
        *v = rng.f32() * 2.0 - 1.0;
    }
    DecompositionSnapshot::from_parts(factors, core, vec![0.5; ks[n - 1]], 0.9, 1, 1)
}

fn random_queries(rng: &mut Rng, dims: &[usize], count: usize) -> QueryBatch {
    let mut batch = QueryBatch::new();
    for _ in 0..count {
        let idx: Vec<usize> =
            dims.iter().map(|&l| rng.usize_below(l)).collect();
        batch.add(&idx);
    }
    batch
}

/// Kernels to pin against each other: the scalar reference and
/// whatever the host actually dispatches (AVX2/NEON/portable).
fn kernels_under_test() -> Vec<Kernel> {
    let mut ks = vec![Kernel::Scalar, Kernel::Portable];
    let detected = Kernel::detect();
    if !ks.contains(&detected) {
        ks.push(detected);
    }
    ks
}

#[test]
fn batched_matches_oracle_bit_exact_3d() {
    Runner::new(12, 30).run("serve-batch-oracle-3d", |case, rng| {
        let dims = vec![
            4 + rng.usize_below(case.size + 8),
            3 + rng.usize_below(10),
            2 + rng.usize_below(8),
        ];
        let ks = vec![
            1 + rng.usize_below(5),
            1 + rng.usize_below(4),
            1 + rng.usize_below(4),
        ];
        let snap = random_model(rng, &dims, &ks);
        let batch = random_queries(rng, &dims, 40 + rng.usize_below(120));
        for kernel in kernels_under_test() {
            let got = snap
                .reconstruct_batch_with(&batch, kernel)
                .map_err(|e| format!("valid batch rejected: {e}"))?;
            for (q, v) in batch.queries().iter().zip(&got) {
                let want = snap
                    .reconstruct_at(q)
                    .map_err(|e| format!("oracle rejected {q:?}: {e}"))?;
                prop_assert!(
                    v.to_bits() == want.to_bits(),
                    "kernel {} at {q:?}: batched {v:e} ({:#x}) vs oracle {want:e} ({:#x})",
                    kernel.name(),
                    v.to_bits(),
                    want.to_bits()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn batched_matches_oracle_bit_exact_4d_pin() {
    let mut rng = Rng::new(0x5E24E);
    let dims = [7usize, 6, 5, 4];
    let ks = [3usize, 2, 4, 2];
    let snap = random_model(&mut rng, &dims, &ks);
    let batch = random_queries(&mut rng, &dims, 150);
    for kernel in kernels_under_test() {
        let got = snap.reconstruct_batch_with(&batch, kernel).unwrap();
        for (q, v) in batch.queries().iter().zip(&got) {
            let want = snap.reconstruct_at(q).unwrap();
            assert_eq!(
                v.to_bits(),
                want.to_bits(),
                "kernel {} at {q:?}: batched {v:e} vs oracle {want:e}",
                kernel.name()
            );
        }
    }
}

#[test]
fn query_errors_are_typed() {
    let mut rng = Rng::new(11);
    let snap = random_model(&mut rng, &[6, 5, 4], &[3, 2, 2]);
    // wrong arity
    assert_eq!(
        snap.reconstruct_at(&[1, 2]),
        Err(QueryError::Arity { got: 2, want: 3 })
    );
    // out-of-range coordinate names the offending mode and extent
    assert_eq!(
        snap.reconstruct_at(&[1, 5, 0]),
        Err(QueryError::OutOfRange { mode: 1, index: 5, extent: 5 })
    );
    // a batch with one bad query fails atomically — nothing is served
    let batch = QueryBatch::new().push(&[0, 0, 0]).push(&[6, 0, 0]);
    assert_eq!(
        snap.reconstruct_batch(&batch),
        Err(QueryError::OutOfRange { mode: 0, index: 6, extent: 6 })
    );
    // top-K: slice mode out of order, then slice index out of range
    assert_eq!(
        snap.top_k_per_slice(3, 0, 5).unwrap_err(),
        QueryError::Mode { got: 3, order: 3 }
    );
    assert_eq!(
        snap.top_k_per_slice(2, 4, 5).unwrap_err(),
        QueryError::OutOfRange { mode: 2, index: 4, extent: 4 }
    );
    // the errors render human-readably
    let msg = QueryError::OutOfRange { mode: 1, index: 9, extent: 5 }.to_string();
    assert!(msg.contains("mode 1") && msg.contains('9') && msg.contains('5'), "{msg}");
}

/// Full-sort oracle for one slice: every entry reconstructed through
/// the scalar oracle, sorted by value descending then index ascending.
fn top_k_oracle(
    snap: &DecompositionSnapshot,
    mode: usize,
    index: usize,
    k: usize,
) -> Vec<(Vec<usize>, f32)> {
    let dims = snap.dims();
    let n = dims.len();
    let mut idx = vec![0usize; n];
    idx[mode] = index;
    let free: Vec<usize> = (0..n).filter(|&m| m != mode).collect();
    let mut all: Vec<(Vec<usize>, f32)> = Vec::new();
    'slice: loop {
        all.push((idx.clone(), snap.reconstruct_at(&idx).unwrap()));
        let mut pos = 0usize;
        loop {
            if pos == free.len() {
                break 'slice;
            }
            let m = free[pos];
            idx[m] += 1;
            if idx[m] < dims[m] {
                break;
            }
            idx[m] = 0;
            pos += 1;
        }
    }
    all.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    all.truncate(k);
    all
}

#[test]
fn top_k_matches_full_sort_oracle() {
    Runner::new(10, 20).run("serve-topk-oracle", |case, rng| {
        let dims = vec![
            3 + rng.usize_below(case.size + 6),
            3 + rng.usize_below(8),
            2 + rng.usize_below(6),
        ];
        let ks = vec![1 + rng.usize_below(4), 1 + rng.usize_below(3), 1 + rng.usize_below(3)];
        let snap = random_model(rng, &dims, &ks);
        let mode = rng.usize_below(3);
        let index = rng.usize_below(dims[mode]);
        let slice_len: usize =
            (0..3).filter(|&m| m != mode).map(|m| dims[m]).product();
        for k in [1usize, 3, slice_len + 7] {
            let want = top_k_oracle(&snap, mode, index, k);
            for kernel in kernels_under_test() {
                let got = snap
                    .top_k_per_slice_with(mode, index, k, kernel)
                    .map_err(|e| format!("valid top-k rejected: {e}"))?;
                prop_assert!(
                    got.len() == want.len(),
                    "kernel {}: k={k} returned {} of {} expected",
                    kernel.name(),
                    got.len(),
                    want.len()
                );
                for (rank, (g, w)) in got.iter().zip(&want).enumerate() {
                    prop_assert!(
                        g.idx == w.0 && g.value.to_bits() == w.1.to_bits(),
                        "kernel {} rank {rank}: got {:?}={:e}, want {:?}={:e}",
                        kernel.name(),
                        g.idx,
                        g.value,
                        w.0,
                        w.1
                    );
                }
            }
        }
        Ok(())
    });
}

fn serving_workload(rng: &mut Rng) -> Workload {
    let t = SparseTensor::random(vec![12, 10, 8], 260, rng);
    Workload::from_tensor("serving", t)
}

fn serving_session(w: &Workload) -> TuckerSession {
    TuckerSession::builder(w.clone())
        .scheme(SchemeChoice::Lite)
        .ranks(2)
        .core(CoreRanks::Uniform(3))
        .invocations(1)
        .seed(23)
        .build()
        .expect("valid serving session")
}

#[test]
fn snapshot_queries_are_stable_under_concurrent_mutation() {
    let mut rng = Rng::new(0xC0);
    let w = serving_workload(&mut rng);
    let mut session = serving_session(&w);
    session.decompose();
    let snap = session.latest_snapshot().expect("published at the sweep boundary");
    let gen0 = snap.generation();
    // freeze an independent deep copy: the later equality check proves
    // the Arc'd snapshot never changed, not merely that it changed in
    // some self-consistent way
    let frozen: DecompositionSnapshot = (*snap).clone();
    let batch = random_queries(&mut rng, &[12, 10, 8], 60);
    let before = snap.reconstruct_batch_with(&batch, Kernel::Scalar).unwrap();

    // reader thread hammers the snapshot while the session mutates
    let reader_snap = Arc::clone(&snap);
    let reader_batch = batch.clone();
    let reader = std::thread::spawn(move || {
        let mut runs = Vec::new();
        for _ in 0..40 {
            runs.push(
                reader_snap.reconstruct_batch_with(&reader_batch, Kernel::Scalar).unwrap(),
            );
        }
        runs
    });

    // writer side: ingest (coords stay inside the original dims, so the
    // query batch stays valid), rebalance, refine — every mutation the
    // serving path must be isolated from
    let mut delta = TensorDelta::new();
    for _ in 0..25 {
        let coord: Vec<u32> = [12u32, 10, 8]
            .iter()
            .map(|&l| rng.below(l as u64) as u32)
            .collect();
        delta = delta.append(&coord, rng.f32() * 2.0 - 1.0);
    }
    session.ingest(&delta).expect("in-bounds delta");
    assert!(session.generation() > gen0, "ingest must advance the generation");
    session.rebalance();
    session.decompose_more(1);

    for run in reader.join().expect("reader thread") {
        for (a, b) in run.iter().zip(&before) {
            assert_eq!(a.to_bits(), b.to_bits(), "concurrent read drifted");
        }
    }
    // the held snapshot still equals its pre-mutation deep copy
    assert_eq!(*snap, frozen, "published snapshot mutated in place");
    let after = snap.reconstruct_batch_with(&batch, Kernel::Scalar).unwrap();
    for (a, b) in after.iter().zip(&before) {
        assert_eq!(a.to_bits(), b.to_bits(), "post-mutation read drifted");
    }
    // while the session has moved on to a newer published generation
    let newest = session.latest_snapshot().unwrap();
    assert!(
        newest.generation() > gen0,
        "refine must publish a newer generation ({} vs {gen0})",
        newest.generation()
    );
}

#[test]
fn snapshot_serialize_roundtrip_is_bit_exact() {
    let mut factors = vec![Mat::zeros(3, 2), Mat::zeros(2, 2), Mat::zeros(2, 2)];
    // adversarial payloads: -0.0, subnormal, values decimal formatting
    // would perturb
    factors[0].data = vec![1.0, -0.0, f32::MIN_POSITIVE, 0.1 + 0.2, -7.25, 3.4e38];
    factors[1].data = vec![0.1, 1e-30, -0.0, 2.5];
    factors[2].data = vec![-1.5, 0.3, 0.7, -0.2];
    let core = Mat { rows: 2, cols: 4, data: vec![0.25, -0.0, 1e-38, 3.0, -2.0, 0.5, 0.1, 9.0] };
    let snap = DecompositionSnapshot::from_parts(
        factors,
        core,
        vec![1.25, f32::MIN_POSITIVE],
        0.123456789012345,
        42,
        7,
    );
    let text = snap.serialize();
    let back = DecompositionSnapshot::parse(&text).expect("own output parses");
    assert_eq!(back, snap, "round trip must reproduce every bit");
    assert_eq!(back.generation(), 42);
    assert_eq!(back.sweep(), 7);
    assert_eq!(back.fit().to_bits(), snap.fit().to_bits());
    // and the round-tripped model answers queries identically
    let q = [2usize, 1, 0];
    assert_eq!(
        back.reconstruct_at(&q).unwrap().to_bits(),
        snap.reconstruct_at(&q).unwrap().to_bits()
    );
    // garbage is a typed Err, not a panic
    assert!(DecompositionSnapshot::parse("{]").is_err());
    assert!(DecompositionSnapshot::parse("{}").is_err());
}

#[test]
fn coordinator_enforces_thread_and_memory_budgets() {
    let mut rng = Rng::new(3);
    let w = serving_workload(&mut rng);
    let budget =
        ServeBudget { worker_threads: 4, snapshot_bytes: 10_000, max_batch: 8 };
    let mut coord = ServeCoordinator::new(budget).with_kernel(Kernel::Scalar);
    assert_eq!(coord.budget(), budget);

    coord.admit("alpha", serving_session(&w), 2, 4_000).expect("fits");
    coord.admit("beta", serving_session(&w), 2, 4_000).expect("fits exactly");
    assert_eq!(coord.threads_reserved(), 4);
    assert_eq!(coord.bytes_reserved(), 8_000);

    // thread budget exhausted
    let (_, err) = coord.admit("gamma", serving_session(&w), 1, 100).unwrap_err();
    assert_eq!(
        err,
        AdmissionError::ThreadBudget { tenant: "gamma".into(), requested: 1, available: 0 }
    );
    // duplicate names are rejected before any accounting
    let (_, err) = coord.admit("alpha", serving_session(&w), 1, 100).unwrap_err();
    assert_eq!(err, AdmissionError::DuplicateTenant("alpha".into()));
    // zero workers can never be admitted
    let (_, err) = coord.admit("idle", serving_session(&w), 0, 100).unwrap_err();
    assert_eq!(err, AdmissionError::ZeroWorkers("idle".into()));

    // freeing a tenant releases both reservations
    let _session = coord.evict_tenant("beta").expect("admitted above");
    assert_eq!(coord.threads_reserved(), 2);
    // now memory is the binding constraint
    let (_, err) = coord.admit("gamma", serving_session(&w), 1, 7_000).unwrap_err();
    assert_eq!(
        err,
        AdmissionError::MemoryBudget {
            tenant: "gamma".into(),
            requested: 7_000,
            available: 6_000
        }
    );
    coord.admit("gamma", serving_session(&w), 1, 6_000).expect("fits after eviction");
    assert_eq!(coord.tenants(), vec!["alpha", "gamma"]);

    // serving before any decompose is a typed error, as is an unknown
    // tenant
    let batch = QueryBatch::new().push(&[0, 0, 0]);
    assert_eq!(
        coord.query("alpha", &batch).unwrap_err(),
        ServeError::NoSnapshot("alpha".into())
    );
    assert_eq!(
        coord.query("nobody", &batch).unwrap_err(),
        ServeError::UnknownTenant("nobody".into())
    );
}

#[test]
fn coordinator_serves_chunks_tracks_lag_and_lru_evicts() {
    let mut rng = Rng::new(5);
    let w = serving_workload(&mut rng);
    // size the tenant quota to hold exactly two resident snapshots:
    // probe one snapshot's footprint first (factor shapes never change,
    // so every generation costs the same)
    let probe = {
        let mut s = serving_session(&w);
        s.decompose();
        s.latest_snapshot().unwrap().approx_bytes()
    };
    let budget = ServeBudget {
        worker_threads: 8,
        snapshot_bytes: probe * 100,
        max_batch: 4,
    };
    let mut coord = ServeCoordinator::new(budget).with_kernel(Kernel::Scalar);
    coord.admit("solo", serving_session(&w), 2, probe * 2 + probe / 2).expect("admitted");

    let g1 = coord.decompose("solo").expect("first decompose").generation();
    // chunked serving: 10 queries through max_batch=4 → 3 engine calls
    let batch = random_queries(&mut rng, &[12, 10, 8], 10);
    let served = coord.query("solo", &batch).expect("served");
    let direct = coord
        .session("solo")
        .unwrap()
        .latest_snapshot()
        .unwrap()
        .reconstruct_batch_with(&batch, Kernel::Scalar)
        .unwrap();
    assert_eq!(served.len(), 10);
    for (a, b) in served.iter().zip(&direct) {
        assert_eq!(a.to_bits(), b.to_bits(), "chunking changed results");
    }
    {
        let rec = coord.record("solo").unwrap();
        assert_eq!(rec.queries_served, 10);
        assert_eq!(rec.batches, 3);
        assert_eq!(rec.max_batch, 4);
        assert_eq!(rec.generation_lag(), 0, "fresh snapshot serves at zero lag");
        assert!(rec.p50_latency() >= 0.0 && rec.p99_latency() >= rec.p50_latency());
    }

    // ingest advances the session generation; the resident snapshot now
    // lags until the next decompose
    let delta = TensorDelta::new().append(&[1, 1, 1], 0.75);
    coord.ingest("solo", &delta).expect("in-bounds delta");
    coord.query("solo", &batch).expect("still serving the old generation");
    assert!(
        coord.record("solo").unwrap().generation_lag() >= 1,
        "lag must surface after ingest"
    );

    // publish two more generations; quota=2.5 snapshots → LRU keeps two
    let g2 = coord.decompose("solo").expect("second").generation();
    assert!(g2 > g1);
    assert_eq!(coord.resident_generations("solo"), vec![g1, g2]);
    // touch g1 so g2 is the cold one when g3 arrives
    assert!(coord.fetch("solo", g1).is_some());
    coord.ingest("solo", &TensorDelta::new().append(&[2, 2, 2], -0.5)).unwrap();
    let g3 = coord.decompose("solo").expect("third").generation();
    assert_eq!(
        coord.resident_generations("solo"),
        vec![g1, g3],
        "LRU must evict the coldest non-latest generation (g2)"
    );
    assert!(coord.fetch("solo", g2).is_none(), "evicted generations are gone");
    // top-K serves and counts
    let top = coord.top_k("solo", 0, 3, 5).expect("top-k served");
    assert_eq!(top.len(), 5);
    assert_eq!(coord.record("solo").unwrap().topk_queries, 1);
}
