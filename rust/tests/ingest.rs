//! Streaming-ingest integration: `TuckerSession::ingest` +
//! `decompose` must be **bit-identical** to a fresh session built on
//! the mutated tensor under the same placement (factors and core
//! compared element-for-element); incrementally spliced/rebuilt plans
//! keep the lane-blocked layout invariants; the Lite load limit
//! (Theorem 6.1 Metric 1) revalidates unconditionally after placement.

use tucker_lite::coordinator::{SchemeChoice, TuckerSession, Workload};
use tucker_lite::hooi::{check_lane_invariants_for, CoreRanks};
use tucker_lite::prop_assert;
use tucker_lite::sched::{incremental, Distribution, Scheme};
use tucker_lite::tensor::{SliceIndex, SparseTensor, TensorDelta};
use tucker_lite::util::check::Runner;
use tucker_lite::util::rng::Rng;

/// A scheme that replays a fixed distribution — how "the same
/// placement" is pinned when comparing a streamed session against a
/// fresh build on the mutated tensor.
struct Fixed(Distribution);

impl Scheme for Fixed {
    fn name(&self) -> &'static str {
        "Fixed"
    }

    fn uni(&self) -> bool {
        self.0.uni
    }

    fn policies(
        &self,
        _t: &SparseTensor,
        _idx: &[SliceIndex],
        _p: usize,
        _rng: &mut Rng,
    ) -> Distribution {
        self.0.clone()
    }
}

/// A random delta: `n_app` appends at uniform coordinates, `n_chg`
/// value changes and `n_rem` removals at coordinates of existing
/// elements.
fn random_delta(
    t: &SparseTensor,
    rng: &mut Rng,
    n_app: usize,
    n_chg: usize,
    n_rem: usize,
) -> TensorDelta {
    let mut d = TensorDelta::new();
    for _ in 0..n_app {
        let coord: Vec<u32> =
            t.dims.iter().map(|&l| rng.below(l as u64) as u32).collect();
        d = d.append(&coord, rng.f32() * 2.0 - 1.0);
    }
    let existing = |rng: &mut Rng| -> Vec<u32> {
        let e = rng.usize_below(t.nnz());
        (0..t.ndim()).map(|m| t.coord(m, e)).collect()
    };
    for _ in 0..n_chg {
        let coord = existing(rng);
        d = d.change(&coord, rng.f32() * 2.0 - 1.0);
    }
    for _ in 0..n_rem {
        let coord = existing(rng);
        d = d.remove(&coord);
    }
    d
}

fn build_streamed(
    w: &Workload,
    p: usize,
    k: usize,
    invocations: usize,
) -> TuckerSession {
    TuckerSession::builder(w.clone())
        .scheme(SchemeChoice::Lite)
        .ranks(p)
        .core(CoreRanks::Uniform(k))
        .invocations(invocations)
        .seed(31)
        .build()
        .expect("valid streamed session")
}

/// Fresh session on the streamed session's (mutated) tensor under its
/// (extended) placement.
fn build_fresh(streamed: &TuckerSession, p: usize, k: usize, invocations: usize) -> TuckerSession {
    let w2 = Workload::from_tensor("fresh", streamed.workload().tensor.clone());
    TuckerSession::builder(w2)
        .scheme(SchemeChoice::custom(Box::new(Fixed(
            streamed.distribution().clone(),
        ))))
        .ranks(p)
        .core(CoreRanks::Uniform(k))
        .invocations(invocations)
        .seed(31)
        .build()
        .expect("valid fresh session")
}

#[test]
fn ingest_then_decompose_is_bit_identical_to_fresh_session() {
    Runner::new(10, 30).run("ingest-fresh-equivalence", |case, rng| {
        let p = 2 + rng.usize_below(4);
        let k = 2 + rng.usize_below(3);
        let dims = vec![
            (8 + rng.usize_below(case.size + 8)) as u32,
            (6 + rng.usize_below(12)) as u32,
            (4 + rng.usize_below(8)) as u32,
        ];
        let nnz = 150 + rng.usize_below(case.size * 10 + 50);
        let t = SparseTensor::random(dims, nnz, rng);
        let w = Workload::from_tensor("stream", t);
        let mut streamed = build_streamed(&w, p, k, 2);
        let n_app = 1 + rng.usize_below(30);
        let n_chg = rng.usize_below(10);
        let n_rem = rng.usize_below(5);
        let delta =
            random_delta(&streamed.workload().tensor, rng, n_app, n_chg, n_rem);
        let rep = streamed.ingest(&delta).map_err(|e| e.to_string())?;
        prop_assert!(
            rep.plans_touched() <= rep.plan_count,
            "touched {} of {} plans",
            rep.plans_touched(),
            rep.plan_count
        );
        // Metric 1 revalidates unconditionally after placement
        let t2 = &streamed.workload().tensor;
        let limit = t2.nnz().div_ceil(p);
        for (n, pol) in streamed.distribution().policies.iter().enumerate() {
            let e_max = pol.rank_counts().into_iter().max().unwrap_or(0);
            prop_assert!(
                e_max <= limit,
                "mode {n}: E_max {e_max} > ⌈|E′|/P⌉ {limit}"
            );
        }
        // the headline contract: ingest + decompose_more is bit-identical
        // to a fresh build on the mutated tensor under the same placement
        // (a virgin session's decompose_more(1) bootstraps and runs the
        // configured 2 invocations + 1; the fresh session runs 3)
        let mut fresh = build_fresh(&streamed, p, k, 3);
        let d_inc = streamed.decompose_more(1);
        let d_fresh = fresh.decompose();
        prop_assert!(
            d_inc.fit() == d_fresh.fit(),
            "fit {} vs fresh {}",
            d_inc.fit(),
            d_fresh.fit()
        );
        for (n, (a, b)) in d_inc.factors.iter().zip(&d_fresh.factors).enumerate() {
            prop_assert!(a.data == b.data, "mode {n} factors diverge");
        }
        prop_assert!(d_inc.core.data == d_fresh.core.data, "cores diverge");
        Ok(())
    });
}

#[test]
fn incrementally_maintained_plans_keep_lane_invariants() {
    Runner::new(8, 25).run("ingest-lane-invariants", |case, rng| {
        let p = 2 + rng.usize_below(3);
        let dims = vec![
            (6 + rng.usize_below(case.size + 6)) as u32,
            (5 + rng.usize_below(10)) as u32,
            (4 + rng.usize_below(6)) as u32,
        ];
        let nnz = 120 + rng.usize_below(case.size * 8 + 40);
        let t = SparseTensor::random(dims, nnz, rng);
        let w = Workload::from_tensor("lanes", t);
        let mut s = build_streamed(&w, p, 3, 1);
        // several consecutive ingests stress splice-on-spliced plans
        for round in 0..3 {
            let n_app = 1 + rng.usize_below(12);
            let n_chg = rng.usize_below(6);
            let n_rem = rng.usize_below(3);
            let delta =
                random_delta(&s.workload().tensor, rng, n_app, n_chg, n_rem);
            s.ingest(&delta).map_err(|e| format!("round {round}: {e}"))?;
        }
        let t = &s.workload().tensor;
        for st in s.mode_states() {
            for (rank, plan) in st.plans.iter().enumerate() {
                check_lane_invariants_for(t, plan, &st.elems[rank]);
            }
        }
        Ok(())
    });
}

#[test]
fn four_dimensional_ingest_matches_fresh_session() {
    let mut rng = Rng::new(17);
    let t = SparseTensor::random(vec![10, 8, 6, 5], 400, &mut rng);
    let w = Workload::from_tensor("stream4d", t);
    let mut streamed = build_streamed(&w, 3, 3, 1);
    let delta = random_delta(&streamed.workload().tensor, &mut rng, 25, 6, 3);
    let rep = streamed.ingest(&delta).unwrap();
    if streamed.shared_plans().is_some() {
        // under TUCKER_PLAN=shared the unit of maintenance is the
        // rank's one tree: a broad delta dirties all P of them
        assert!(rep.plans_touched() >= 3, "every rank's tree is dirty");
    } else {
        assert!(rep.plans_touched() >= 4, "every mode has a dirty rank");
    }
    let mut fresh = build_fresh(&streamed, 3, 3, 1);
    let d_inc = streamed.decompose();
    let d_fresh = fresh.decompose();
    assert_eq!(d_inc.fit(), d_fresh.fit());
    for (a, b) in d_inc.factors.iter().zip(&d_fresh.factors) {
        assert_eq!(a.data, b.data);
    }
    assert_eq!(d_inc.core.data, d_fresh.core.data);
    // lane invariants on the 4-D spliced plans too
    let t = &streamed.workload().tensor;
    for st in streamed.mode_states() {
        for (rank, plan) in st.plans.iter().enumerate() {
            check_lane_invariants_for(t, plan, &st.elems[rank]);
        }
    }
}

#[test]
fn value_only_delta_splices_without_structural_rebuild() {
    let mut rng = Rng::new(23);
    let t = SparseTensor::random(vec![20, 15, 10], 900, &mut rng);
    let w = Workload::from_tensor("values", t);
    let mut s = build_streamed(&w, 4, 4, 1);
    let before: Vec<usize> = s
        .mode_states()
        .iter()
        .map(|st| st.sharers.r_sum())
        .collect();
    let delta = random_delta(&s.workload().tensor, &mut rng, 0, 5, 2);
    let rep = s.ingest(&delta).unwrap();
    assert_eq!(rep.appended, 0);
    assert!(rep.plans_rebuilt == 0, "small value batches splice in place");
    assert!(rep.plans_spliced >= 1);
    assert!(rep.rebalance_modes.is_empty(), "no structural change");
    // sharing structure untouched by value-only deltas
    let after: Vec<usize> =
        s.mode_states().iter().map(|st| st.sharers.r_sum()).collect();
    assert_eq!(before, after);
    // and the decomposition still matches a fresh build exactly
    let mut fresh = build_fresh(&s, 4, 4, 1);
    let d_inc = s.decompose();
    let d_fresh = fresh.decompose();
    assert_eq!(d_inc.fit(), d_fresh.fit());
    for (a, b) in d_inc.factors.iter().zip(&d_fresh.factors) {
        assert_eq!(a.data, b.data);
    }
}

#[test]
fn warm_start_refinement_continues_after_ingest() {
    // the long-running-service flow: decompose, stream a delta, refine —
    // the factors carry over as a warm start and refinement proceeds
    // over the updated plans
    let mut rng = Rng::new(29);
    let t = SparseTensor::random(vec![18, 14, 9], 700, &mut rng);
    let w = Workload::from_tensor("service", t);
    let mut s = build_streamed(&w, 4, 4, 1);
    let d0 = s.decompose();
    assert!(d0.fit().is_finite());
    let delta = random_delta(&s.workload().tensor, &mut rng, 20, 4, 2);
    s.ingest(&delta).unwrap();
    let d1 = s.decompose_more(2);
    assert!(d1.fit().is_finite() && (0.0..=1.0).contains(&d1.fit()));
    assert_eq!(s.plan_builds(), 1, "ingest never re-runs prepare_modes");
    assert!(s.plan_rebuilds() > 0);
}

#[test]
fn theorem_bounds_revalidation_reports_per_mode() {
    let mut rng = Rng::new(41);
    let t = SparseTensor::random(vec![15, 12, 8], 600, &mut rng);
    let w = Workload::from_tensor("bounds", t);
    let mut s = build_streamed(&w, 3, 3, 1);
    let delta = random_delta(&s.workload().tensor, &mut rng, 30, 0, 0);
    let rep = s.ingest(&delta).unwrap();
    // whatever the report says must agree with a direct recomputation
    for n in 0..3 {
        let ok = incremental::theorem_bounds(
            &s.workload().idx[n],
            &s.distribution().policies[n],
        )
        .all_ok();
        assert_eq!(
            !rep.rebalance_modes.contains(&n),
            ok,
            "mode {n} rebalance flag disagrees with the bounds"
        );
    }
}
