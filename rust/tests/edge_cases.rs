//! Edge cases and failure injection across the stack: degenerate tensors,
//! pathological distributions, tiny/extreme parameters — places where the
//! paper's assumptions (K ≤ L_n, nnz ≫ P, no empty slices) break down and
//! the implementation must stay well-defined.

use tucker_lite::coordinator::{run_scheme, Workload};
use tucker_lite::dist::NetModel;
use tucker_lite::hooi::{assemble_local_z, dense_penultimate, HooiConfig};
use tucker_lite::linalg::orthonormal_random;
use tucker_lite::runtime::Engine;
use tucker_lite::sched::{self, ModeMetrics, Scheme};
use tucker_lite::tensor::slices::build_all;
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::rng::Rng;

fn run(w: &Workload, p: usize, k: usize) -> tucker_lite::coordinator::RunRecord {
    run_scheme(w, &sched::Lite, p, k, 1, &Engine::Native, NetModel::default(), 3)
}

fn workload(t: SparseTensor) -> Workload {
    let idx = build_all(&t);
    Workload { name: "edge".into(), tensor: t, idx }
}

#[test]
fn single_element_tensor() {
    let mut t = SparseTensor::new(vec![5, 5, 5]);
    t.push(&[2, 3, 4], 7.0);
    let rec = run(&workload(t), 4, 2);
    // a single element is exactly rank-1: perfect fit
    assert!(rec.fit > 0.999, "fit {}", rec.fit);
}

#[test]
fn more_ranks_than_elements() {
    let mut rng = Rng::new(1);
    let t = SparseTensor::random(vec![6, 6, 6], 5, &mut rng);
    for scheme in sched::all_schemes() {
        let idx = build_all(&t);
        let d = scheme.policies(&t, &idx, 16, &mut Rng::new(2));
        assert!(d.validate(&t).is_ok(), "{}", scheme.name());
    }
    let rec = run(&workload(t), 16, 2);
    assert!(rec.fit.is_finite());
}

#[test]
fn all_zero_values() {
    // Lanczos on the zero matrix must not NaN
    let mut t = SparseTensor::new(vec![8, 8, 8]);
    for i in 0..8u32 {
        t.push(&[i, i, i], 0.0);
    }
    let rec = run(&workload(t), 2, 2);
    assert!(rec.fit.is_finite());
}

#[test]
fn duplicate_coordinates_are_additive() {
    // Eq. 1 sums contributions; duplicates must behave like their sum
    let mut a = SparseTensor::new(vec![4, 4, 4]);
    a.push(&[1, 2, 3], 2.0);
    a.push(&[1, 2, 3], 3.0);
    let mut b = SparseTensor::new(vec![4, 4, 4]);
    b.push(&[1, 2, 3], 5.0);
    let k = 2;
    let mut rng = Rng::new(5);
    let factors: Vec<_> = a
        .dims
        .iter()
        .map(|&l| orthonormal_random(l as usize, k, &mut rng))
        .collect();
    let za = dense_penultimate(&a, 0, &factors);
    let zb = dense_penultimate(&b, 0, &factors);
    assert!(za.max_abs_diff(&zb) < 1e-5);
}

#[test]
fn one_giant_slice_only() {
    // every element in a single mode-0 slice: Lite must still balance
    let mut t = SparseTensor::new(vec![3, 50, 50]);
    let mut rng = Rng::new(7);
    for _ in 0..1000 {
        t.push(&[0, rng.below(50) as u32, rng.below(50) as u32], rng.f32());
    }
    let idx = build_all(&t);
    let d = sched::Lite.policies(&t, &idx, 8, &mut Rng::new(8));
    let m = ModeMetrics::compute(&idx[0], &d.policies[0]);
    assert_eq!(m.e_max, 125, "perfect split of the giant slice");
    // CoarseG cannot split it
    let dc = sched::CoarseG::default().policies(&t, &idx, 8, &mut Rng::new(9));
    let mc = ModeMetrics::compute(&idx[0], &dc.policies[0]);
    assert_eq!(mc.e_max, 1000);
}

#[test]
fn k_larger_than_some_mode() {
    // L = [3, 40, 40] with K = 8 > 3: zero-padded factor columns
    let mut rng = Rng::new(11);
    let t = SparseTensor::random(vec![3, 40, 40], 600, &mut rng);
    let rec = run(&workload(t), 4, 8);
    assert!(rec.fit.is_finite());
    assert!((0.0..=1.0).contains(&rec.fit));
}

#[test]
fn k_equals_one() {
    let mut rng = Rng::new(12);
    let t = SparseTensor::random(vec![20, 20, 20], 400, &mut rng);
    let rec = run(&workload(t), 4, 1);
    assert!(rec.fit.is_finite());
}

#[test]
fn p_equals_one_degenerate_cluster() {
    let mut rng = Rng::new(13);
    let t = SparseTensor::random(vec![15, 15, 15], 500, &mut rng);
    let rec = run(&workload(t), 1, 4);
    // no communication on a single rank
    assert_eq!(rec.svd_volume, 0.0);
    assert_eq!(rec.fm_volume, 0.0);
    assert!(rec.fit.is_finite());
}

#[test]
fn empty_rank_in_ttm_assembly() {
    let mut rng = Rng::new(14);
    let t = SparseTensor::random(vec![10, 10, 10], 100, &mut rng);
    let factors: Vec<_> = t
        .dims
        .iter()
        .map(|&l| orthonormal_random(l as usize, 3, &mut rng))
        .collect();
    let z = assemble_local_z(&t, 0, &[], &factors, 3, &Engine::Native);
    assert_eq!(z.rows.len(), 0);
}

#[test]
fn hooi_config_defaults_sane() {
    let cfg = HooiConfig::default();
    assert_eq!(cfg.core, tucker_lite::hooi::CoreRanks::Uniform(10));
    assert_eq!(cfg.invocations, 1);
    assert!(cfg.kernel.is_none() && cfg.accounting.is_none());
}

#[test]
fn mediumg_with_prime_p() {
    // P = 13 (prime): the grid degenerates to one long axis — must work
    let mut rng = Rng::new(15);
    let t = SparseTensor::random(vec![40, 30, 20], 800, &mut rng);
    let idx = build_all(&t);
    let d = sched::MediumG.policies(&t, &idx, 13, &mut Rng::new(16));
    assert!(d.validate(&t).is_ok());
    let grid = sched::medium::factorize_grid(13, &t.dims);
    assert_eq!(grid.iter().product::<usize>(), 13);
}

#[test]
fn hyperg_tiny_tensor_fewer_vertices_than_parts() {
    let mut rng = Rng::new(17);
    let t = SparseTensor::random(vec![4, 4, 4], 6, &mut rng);
    let idx = build_all(&t);
    let d = sched::HyperG::default().policies(&t, &idx, 8, &mut Rng::new(18));
    assert!(d.validate(&t).is_ok());
}

#[test]
fn four_d_with_tiny_last_mode() {
    // mirrors the scaled enron analogue: L4 = 4 << K
    let mut rng = Rng::new(19);
    let t = SparseTensor::random(vec![30, 25, 60, 4], 1500, &mut rng);
    let rec = run(&workload(t), 8, 10);
    assert!(rec.fit.is_finite());
    assert!(rec.ttm_balance <= 1.01);
}

#[test]
fn net_model_zero_cost_network() {
    // α = β = 0: communication takes no time but volumes still count
    let mut rng = Rng::new(20);
    let t = SparseTensor::random(vec![20, 20, 20], 600, &mut rng);
    let w = workload(t);
    let rec = run_scheme(
        &w,
        &sched::Lite,
        4,
        4,
        1,
        &Engine::Native,
        NetModel { alpha: 0.0, beta: 0.0 },
        1,
    );
    assert!(rec.svd_volume > 0.0 || rec.fm_volume > 0.0);
}
